"""Paper Fig 7: minibatch-size effect — fixed token budget, varying B.
Small B → poor hardware efficiency (us/token high); very large B (few
updates) → worse final loss. derived = final loss + us/token."""
import time

import jax

from benchmarks.common import emit
from repro.configs.base import ModelConfig
from repro.core import parallelism as par
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.optim import make_optimizer
from repro.train import trainer


def main():
    cfg = ModelConfig(name="bench", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                      vocab_size=64, loss_chunk=32, attn_chunk=32, remat=False)
    token_budget = 64 * 64 * 16          # fixed across batch sizes
    seq = 64
    plan = par.make_plan("dp", make_host_mesh())
    for B in (4, 16, 64):
        steps = token_budget // (B * seq)
        opt = make_optimizer("adam", lr=3e-3)
        state = trainer.init_state(cfg, opt, jax.random.PRNGKey(0))
        step = jax.jit(trainer.make_train_step(cfg, opt, plan))
        data = SyntheticLM(cfg.vocab_size, seq, noise=0.05)
        t0 = time.perf_counter()
        loss = None
        for batch in data.batches(B, steps):
            state, m = step(state, batch)
            loss = float(m["loss"])
        dt = time.perf_counter() - t0
        emit(f"fig7/B={B}", dt / token_budget * 1e6,
             f"steps={steps} final_loss={loss:.3f}")


if __name__ == "__main__":
    main()
