"""§6.4 model consolidation + §6.5 meta-optimization benches: EASGD vs
periodic averaging convergence; grid vs random vs PBT search quality."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import consolidation as con
from repro.core import metaopt as mo


def _quad(seed=0, dim=12):
    key = jax.random.PRNGKey(seed)
    A = jnp.diag(jax.random.uniform(key, (dim,), minval=0.5, maxval=3.0))
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (dim,))
    sol = jnp.linalg.solve(A, b)
    return (lambda w, n=None: 0.5 * w["w"] @ A @ w["w"]
            - (b + (0.0 if n is None else n)) @ w["w"]), {"w": jnp.zeros(dim)}, sol


def main():
    loss, w0, sol = _quad()
    gfn = jax.grad(lambda w: loss(w))

    # §6.4: EASGD
    agents = [jax.tree.map(lambda p: p + 0.5 * i, w0) for i in range(4)]
    center = w0
    for _ in range(300):
        agents, center = con.easgd_round(agents, center, [gfn(w) for w in agents],
                                         lr=0.1, rho=0.05)
    emit("sec64/easgd_4agents", None,
         f"center_err={float(jnp.linalg.norm(center['w'] - sol)):.4f}")

    # §6.4: periodic averaging
    batches = jax.random.normal(jax.random.PRNGKey(2), (60, 12)) * 0.05
    final, losses = con.periodic_average_sgd(lambda w, b: loss(w, b), w0,
                                             batches, agents=3, lr=0.1)
    emit("sec64/periodic_avg_3agents", None,
         f"err={float(jnp.linalg.norm(final['w'] - sol)):.4f} "
         f"loss {losses[0]:.2f}->{losses[-1]:.2f}")

    # §6.5: hyper-parameter search
    def train_eval(hypers, steps, state):
        w = state if state is not None else w0
        for _ in range(steps):
            w = jax.tree.map(lambda p, g: p - hypers["lr"] * g, w, gfn(w))
        return w, -float(loss(w))

    best_g, sg, _ = mo.grid_search(train_eval, {"lr": [1e-3, 1e-2, 0.1, 0.3]}, 40)
    emit("sec65/grid_search", None, f"best_lr={best_g['lr']} score={sg:.3f}")
    best_r, sr, _ = mo.random_search(train_eval, {"lr": (1e-4, 1.0)}, 40, 8)
    emit("sec65/random_search", None, f"best_lr={best_r['lr']:.4f} score={sr:.3f}")
    best_p, hist = mo.population_based_training(
        train_eval, [{"lr": v} for v in (1e-4, 1e-3, 0.05, 0.3)],
        population=4, rounds=6, steps_per_round=15)
    emit("sec65/pbt", None,
         f"best_lr={best_p.hypers['lr']:.4f} score={best_p.score:.3f} "
         f"round0_best={max(s for _, s in hist[0]):.3f}")


if __name__ == "__main__":
    main()
