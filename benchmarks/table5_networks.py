"""Paper Table 5 + §3.3.1: network case studies. Reproduces the published
LeNet-5 W/D numbers per layer (derived = ours == paper) and tabulates the
published characteristics of the five networks."""
from benchmarks.common import emit
from repro.core import workdepth as wd


def main():
    ours = wd.lenet5_layers()
    for name, (w, d) in wd.LENET5_PAPER.items():
        if name == "total":
            continue
        o = ours[name]
        emit(f"table5/lenet5/{name}", None,
             f"ours=({o.work};{o.depth}) paper=({w};{d}) "
             f"match={(o.work, o.depth) == (w, d)}")
    t = wd.lenet5_inference()
    emit("table5/lenet5/total", None,
         f"W={t.work} D={t.depth} paper=(665832;41) "
         f"match={(t.work, t.depth) == (665832, 41)}")

    for net, props in wd.network_table5().items():
        emit(f"table5/{net}", None,
             f"params={props['params']} layers={props['layers']} ops={props['ops']}")


if __name__ == "__main__":
    main()
