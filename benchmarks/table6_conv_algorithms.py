"""Paper Table 6: convolution algorithm Work-Depth — direct / im2col / FFT /
Winograd — across kernel sizes, exhibiting the paper's crossovers (§4.3:
'the larger the kernels, the more beneficial FFT becomes'; Winograd for
small kernels)."""
from benchmarks.common import emit
from repro.core import workdepth as wd


def main():
    N, H, C_in, C_out = 64, 56, 64, 64
    for K in (3, 5, 7, 11, 13):
        direct = wd.conv_direct(N, H, H, C_in, C_out, K, K)
        im2col = wd.conv_im2col(N, H, H, C_in, C_out, K, K)
        fft = wd.conv_fft(N, H, H, C_in, C_out)
        emit(f"table6/K={K}/direct", None, f"W={direct.work:.3e} D={direct.depth}")
        emit(f"table6/K={K}/im2col", None, f"W={im2col.work:.3e} D={im2col.depth}")
        emit(f"table6/K={K}/fft", None,
             f"W={fft.work:.3e} D={fft.depth} fft_wins={fft.work < direct.work}")
        if K == 3:
            wino = wd.conv_winograd(N, H, H, C_in, C_out, r=3, m=2)
            emit("table6/K=3/winograd", None,
                 f"W={wino.work:.3e} D={wino.depth} wins={wino.work < direct.work}")


if __name__ == "__main__":
    main()
