"""Paper Table 3: weight update rules — per-call latency on a 1M-param tree
and descent sanity (derived = loss drop over 50 quadratic steps)."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.optim import OPTIMIZERS, make_optimizer


def main():
    key = jax.random.PRNGKey(0)
    params = {"a": jax.random.normal(key, (1024, 512)),
              "b": jax.random.normal(key, (1024, 512))}
    grads = jax.tree.map(lambda p: p * 0.01, params)

    for name in OPTIMIZERS:
        opt = make_optimizer(name, lr=0.05)
        state = opt.init(params)
        upd = jax.jit(lambda g, s, p: opt.update(g, s, p))
        us, _ = time_fn(upd, grads, state, params)

        # descent check on a quadratic
        w = {"w": jnp.zeros(64)}
        st = opt.init(w)
        A = jnp.linspace(0.5, 3.0, 64)
        loss = lambda w_: 0.5 * jnp.sum(A * w_["w"] ** 2) - jnp.sum(w_["w"])
        l0 = float(loss(w))
        for _ in range(50):
            g = jax.grad(loss)(w)
            w, st = opt.update(g, st, w)
        emit(f"table3/{name}", us, f"loss_drop={l0 - float(loss(w)):.3f}")


if __name__ == "__main__":
    main()
