"""Paper Fig 6 / §2.5: allreduce algorithm comparison.

Two views:
  (a) analytical α-β model times on TPU-v5e link constants across message
      sizes — reproducing the paper's regime analysis (butterfly for small
      γm, ring/rabenseifner for large), and
  (b) measured wall time of our shard_map schedules on 8 host devices
      (spawned subprocess — this process stays single-device).
"""
import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit
from repro.core import costmodel as cm

L, G = 1e-6, 1.0 / 50e9   # ICI-ish constants


def analytical():
    for m in (4_096, 1_048_576, 268_435_456):      # elements
        times = {
            "tree": cm.t_tree(256, m, L, G),
            "butterfly": cm.t_butterfly(256, m, L, G),
            "ring": cm.t_pipeline(256, m, L, G),
            "rabenseifner": cm.t_rabenseifner(256, m, L, G),
        }
        best = min(times, key=times.get)
        lb = cm.t_lower_bound(256, m, L, G)
        for alg, t in times.items():
            emit(f"fig6/analytical/m={m}/{alg}", t * 1e6,
                 f"vs_lower_bound={t/lb:.2f} best={alg == best}")


def measured():
    code = textwrap.dedent("""
        import json, time
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.compat import shard_map
        from repro.core import collectives as coll
        mesh = jax.make_mesh((8,), ('x',))
        out = {}
        x = jnp.ones((8, 262144), jnp.float32)
        for alg in coll.ALGORITHMS:
            f = jax.jit(shard_map(
                lambda v: coll.allreduce_sum(v[0], 'x', algorithm=alg)[None],
                mesh=mesh, in_specs=P('x'), out_specs=P('x'), check_vma=False))
            jax.block_until_ready(f(x))
            t0 = time.perf_counter()
            for _ in range(5):
                jax.block_until_ready(f(x))
            out[alg] = (time.perf_counter() - t0) / 5 * 1e6
        print('RESULT ' + json.dumps(out))
    """)
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600, env=env)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            for alg, us in json.loads(line[7:]).items():
                emit(f"fig6/measured_8dev_1M/{alg}", us, "host-CPU emulation")
            return
    emit("fig6/measured_8dev_1M", None, f"subprocess failed: {r.stderr[-200:]}")


def main():
    analytical()
    measured()


if __name__ == "__main__":
    main()
