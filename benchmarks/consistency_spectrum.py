"""Paper §6.1 / Fig 28: model-consistency spectrum — final error vs gradient
staleness (sync → SSP → async) on a noisy quadratic."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import consistency as cons


def main():
    dim, steps = 20, 200
    key = jax.random.PRNGKey(0)
    A = jnp.diag(jax.random.uniform(key, (dim,), minval=0.5, maxval=3.0))
    b = jax.random.normal(jax.random.PRNGKey(1), (dim,))
    opt = jnp.linalg.solve(A, b)

    def loss(params, batch):
        return 0.5 * params["w"] @ A @ params["w"] - (b + batch) @ params["w"]

    batches = jax.random.normal(jax.random.PRNGKey(2), (steps, dim)) * 0.05
    p0 = {"w": jnp.zeros(dim)}

    for s in (0, 1, 2, 4, 8, 16):
        run = jax.jit(lambda: cons.simulate_stale_sgd(
            loss, p0, batches, lr=0.1, staleness=s)[0])
        us, final = time_fn(run, iters=2)
        err = float(jnp.linalg.norm(final["w"] - opt))
        kind = "sync" if s == 0 else ("ssp" if s < 8 else "async-ish")
        emit(f"consistency/staleness={s}", us, f"err={err:.4f} regime={kind}")

    run = jax.jit(lambda: cons.simulate_async_agents(
        loss, p0, batches, lr=0.05, agents=4)[0])
    us, final = time_fn(run, iters=2)
    emit("consistency/downpour_4agents", us,
         f"err={float(jnp.linalg.norm(final['w'] - opt)):.4f}")


if __name__ == "__main__":
    main()
