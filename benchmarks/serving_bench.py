"""Serving throughput: continuous-batching engine vs legacy static batch,
plus prefix caching on a shared-prefix workload.

Workload `mixed` — chat-shaped mixed lengths (short prompts, skewed
generation budgets, 3x more requests than decode slots) — the regime where
static batching collapses: every batch pads to its longest prompt AND
decodes for its longest budget while finished rows burn compute.

  * legacy — successive `serve.generate` calls over static batches of
    max_slots requests (FCFS, left-padded, max_new = batch max). This is the
    STRONG baseline: it already uses the one-shot batched prefill; the
    seed's token-by-token prefill loop is strictly slower.
  * engine — the same requests through `Engine.step()` with chunked prefill
    and continuous batching.

Workload `shared` — every request repeats a common system-prompt prefix
(chat template / few-shot header) plus a short unique suffix. The engine is
run with prefix caching ON vs OFF (cache primed by one untimed request in
both modes so the comparison is steady-state); rows report cache hit rate,
prefill tokens saved, and the on/off speedup.

Per-family mode (`--config-family full|sliding|ssm|hybrid|all`) runs a
chat-shaped workload through the engine for that model family's state
providers and reports tokens/s, per-slot sequence-state memory (the
provider's per-kind cost: paged KV for full, ring-capped KV for sliding,
O(1) slabs for ssm, the mix for hybrid), and peak block-pool utilization.

Rows: tokens/s, engine decode-batch occupancy, p50/p99 per-token latency
(wall time of the engine step that emitted each token, measured in a
separate synced pass so async dispatch can't hide compute), TTFT and
queue-wait p50/p99 per workload (derived from the engine's request-lifecycle
telemetry in the same synced pass, warmup/prime requests excluded), the
telemetry-overhead check (tokens/s with telemetry off vs on), and the
prefix-cache metrics. Packed-prefill rows: TTFT under packing vs the B=1
chunked baseline (`serving_mixed_unpacked_ttft_*`,
`serving_packed_prefill_ttft_speedup`), per-(chunk x segments) bucket
dispatch counts, and `serving_*_prefill_variants` — prefill trace keys seen
vs declared AOT buckets, where "new=0" certifies the warmup compiled every
variant steady-state serving dispatches. The per-family sweep also reports
the total number of distinct compiled step variants (recompile tracker).

`main(workload=...)` accepts "mixed" | "shared" | "both";
`benchmarks/run.py --serving-workload` passes it through
(`--serving-family` likewise forwards the family sweep). `--trace-out
PREFIX` writes each workload's synced-pass event log to
`PREFIX.<workload>.jsonl` — replayable into per-request TTFT/decode
timelines via `repro.serving.telemetry.replay_jsonl`.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import ModelConfig
from repro.models import state_providers as SP
from repro.models import transformer as T
from repro.serving import serve
from repro.serving.engine import Engine, EngineConfig

FAMILIES = ("full", "sliding", "ssm", "hybrid")


def _cfg():
    return ModelConfig(name="serving-bench", family="dense", num_layers=2,
                       d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
                       d_ff=512, vocab_size=256, loss_chunk=64, attn_chunk=128,
                       remat=False, dtype="float32")


def _family_cfg(family):
    base = dict(num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
                head_dim=64, d_ff=512, vocab_size=256, loss_chunk=64,
                attn_chunk=128, remat=False, dtype="float32")
    if family == "full":
        return ModelConfig(name="sb-full", family="dense", **base)
    if family == "sliding":
        return ModelConfig(name="sb-sliding", family="dense",
                           attention_type="sliding", window_size=32, **base)
    if family == "ssm":
        return ModelConfig(name="sb-ssm", family="ssm", ssm_type="rwkv6",
                           ssm_head_dim=64, **base)
    if family == "hybrid":
        return ModelConfig(name="sb-hybrid", family="hybrid",
                           hybrid_ssm_per_attn=1, ssm_state_dim=16,
                           ssm_head_dim=64, **base)
    raise ValueError(f"unknown family {family!r}")


def _workload(n=24, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(4, 32, size=n)
    news = np.where(rng.random(n) < 0.3, rng.integers(48, 96, size=n),
                    rng.integers(8, 24, size=n))
    prompts = [rng.integers(0, 256, size=int(l)).astype(np.int32) for l in lens]
    return prompts, [int(m) for m in news]


MAX_SLOTS = 8


def _workload_shared(n=24, seed=0, prefix_len=96):
    """Shared-prefix traffic: one common system prompt + short unique
    suffixes, short generations (prefill-dominated — the prefix-cache
    sweet spot)."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, 256, size=prefix_len).astype(np.int32)
    prompts, news = [], []
    for _ in range(n):
        tail = rng.integers(0, 256,
                            size=int(rng.integers(4, 17))).astype(np.int32)
        prompts.append(np.concatenate([prefix, tail]))
        news.append(int(rng.integers(8, 17)))
    return prompts, news, prefix


def _fresh_engine(cfg, params, prompts, *, prefix_caching=True, prime=None,
                  telemetry=True, step_timing=False, packed_prefill=True):
    eng = Engine(cfg, params, EngineConfig(
        block_size=16, num_blocks=256, max_blocks_per_seq=8,
        max_slots=MAX_SLOTS, prefill_chunk=32, prefills_per_step=4,
        prefix_caching=prefix_caching, telemetry=telemetry,
        step_timing=step_timing, packed_prefill=packed_prefill))
    # warmup: compile decode once on a throwaway request (every prefill
    # bucket is already AOT-compiled at engine construction)
    skip = {eng.add_request(prompts[0][:4], 2)}
    eng.drain()
    if prime is not None:
        # populate the prefix index (no-op with caching off; run in both
        # modes so the timed region does identical request work)
        skip.add(eng.add_request(prime, 1))
        eng.drain()
    return eng, skip


def _run_engine(cfg, params, prompts, max_news, *, prefix_caching=True,
                prime=None, telemetry=True):
    """Throughput pass: free-running steps, one sync at the end. Warmup and
    cache-priming tokens/steps are excluded from every reported number."""
    eng, skip = _fresh_engine(cfg, params, prompts,
                              prefix_caching=prefix_caching, prime=prime,
                              telemetry=telemetry)
    warm = dict(eng.stats)
    for p, mn in zip(prompts, max_news):
        eng.add_request(p, mn)
    t0 = time.perf_counter()
    outs = eng.drain()                             # materializes every token
    wall = time.perf_counter() - t0
    total = sum(o.shape[0] for rid, o in outs.items() if rid not in skip)
    occ = ((eng.stats["occupancy_sum"] - warm["occupancy_sum"])
           / max(eng.stats["decode_steps"] - warm["decode_steps"], 1))
    hits = eng.stats["prefix_hit_tokens"] - warm["prefix_hit_tokens"]
    return total, wall, occ, hits


def _run_engine_latency(cfg, params, prompts, max_news, *,
                        prefix_caching=True, prime=None, packed_prefill=True):
    """Latency pass: block on each step's emitted tokens so per-step wall
    time reflects device completion, not async dispatch. Runs with
    `step_timing=True`, so the engine's own request-lifecycle timestamps
    (TTFT, queue wait) are completion times too — returns the engine for
    telemetry readout alongside the per-token latencies."""
    eng, skip = _fresh_engine(cfg, params, prompts,
                              prefix_caching=prefix_caching, prime=prime,
                              step_timing=True, packed_prefill=packed_prefill)
    for p, mn in zip(prompts, max_news):
        eng.add_request(p, mn)
    lat = []
    while eng.scheduler.has_work:
        s = time.perf_counter()
        emitted = eng.step()
        jax.block_until_ready(eng.next_tok)
        dt = time.perf_counter() - s
        lat.extend([dt] * len(emitted))
    return np.asarray(lat), eng, skip


def _lifecycle_percentiles(eng, skip):
    """Per-request TTFT and queue-wait arrays from the engine's telemetry,
    excluding warmup/prime requests."""
    ttfts, waits = [], []
    for rid in eng.requests:
        if rid in skip:
            continue
        tl = eng.telemetry.request_timeline(rid)
        if tl["ttft"] is not None:
            ttfts.append(tl["ttft"])
        if tl["queue_wait"] is not None:
            waits.append(tl["queue_wait"])
    return np.asarray(ttfts), np.asarray(waits)


def _emit_lifecycle(tag, eng, skip, trace_out=None):
    ttfts, waits = _lifecycle_percentiles(eng, skip)
    for name, arr in ((f"serving_{tag}_ttft", ttfts),
                      (f"serving_{tag}_queue_wait", waits)):
        for q in (50, 99):
            emit(f"{name}_p{q}", float(np.percentile(arr, q)) * 1e6)
    if trace_out:
        path = f"{trace_out}.{tag}.jsonl"
        n = eng.telemetry.export_jsonl(path)
        emit(f"serving_{tag}_trace_events", None, f"{n}@{path}")


def _emit_prefill_variants(tag, eng):
    """Prefill trace keys seen vs. declared buckets (new must be 0 — the
    AOT warmup contract) plus per-bucket dispatch counts."""
    declared = len(eng.prefill_grid)
    seen = eng.telemetry.recompiles.unique("prefill")
    emit(f"serving_{tag}_prefill_variants", None,
         f"{seen}/{declared} declared (new={seen - declared})")
    for (c, g), n in sorted(eng.bucket_dispatches().items()):
        if n:
            emit(f"serving_{tag}_prefill_bucket_c{c}g{g}_dispatches", None,
                 str(n))


def _legacy_once(cfg, params, prompts, max_news):
    done = 0
    for i in range(0, len(prompts), MAX_SLOTS):
        bp, bn = prompts[i:i + MAX_SLOTS], max_news[i:i + MAX_SLOTS]
        S = max(p.shape[0] for p in bp)
        batch = np.zeros((len(bp), S), np.int32)
        for j, p in enumerate(bp):
            batch[j, S - p.shape[0]:] = p          # left-pad: keep tail intact
        jax.block_until_ready(serve.generate(
            cfg, params, jnp.asarray(batch), max_new=max(bn), temperature=0.0))
        done += sum(bn)                             # tokens anyone asked for
    return done


def _run_legacy(cfg, params, prompts, max_news):
    _legacy_once(cfg, params, prompts, max_news)    # warmup
    t0 = time.perf_counter()
    useful = _legacy_once(cfg, params, prompts, max_news)
    wall = time.perf_counter() - t0
    return useful, wall


def _run_legacy_loop(cfg, params, prompts, max_news):
    """The seed's serving loop: token-by-token sequential prefill (kept as
    `prefill_mode='loop'`), one static batch at a time."""
    def once():
        done = 0
        for i in range(0, len(prompts), MAX_SLOTS):
            bp, bn = prompts[i:i + MAX_SLOTS], max_news[i:i + MAX_SLOTS]
            S = max(p.shape[0] for p in bp)
            batch = np.zeros((len(bp), S), np.int32)
            for j, p in enumerate(bp):
                batch[j, S - p.shape[0]:] = p
            jax.block_until_ready(serve.generate(
                cfg, params, jnp.asarray(batch), max_new=max(bn),
                temperature=0.0, prefill_mode="loop"))
            done += sum(bn)
        return done
    once()                                           # warmup
    t0 = time.perf_counter()
    useful = once()
    wall = time.perf_counter() - t0
    return useful, wall


def _main_mixed(cfg, params, trace_out=None):
    prompts, max_news = _workload()

    total, wall, occ, _hits = _run_engine(cfg, params, prompts, max_news)
    tps_engine = total / wall
    total_o, wall_o, _occ, _h = _run_engine(cfg, params, prompts, max_news,
                                            telemetry=False)
    tps_off = total_o / wall_o
    useful, wall_legacy = _run_legacy(cfg, params, prompts, max_news)
    tps_legacy = useful / wall_legacy
    useful_l, wall_loop = _run_legacy_loop(cfg, params, prompts, max_news)
    tps_loop = useful_l / wall_loop
    lat, eng_lat, skip = _run_engine_latency(cfg, params, prompts, max_news)

    emit("serving_engine_tokens_per_s", wall / total * 1e6, f"{tps_engine:.1f}")
    emit("serving_telemetry_off_tokens_per_s", wall_o / total_o * 1e6,
         f"{tps_off:.1f}")
    emit("serving_telemetry_overhead", None,
         f"{wall / total / (wall_o / total_o):.3f}x")
    emit("serving_legacy_batched_tokens_per_s", wall_legacy / useful * 1e6,
         f"{tps_legacy:.1f}")
    emit("serving_legacy_loop_tokens_per_s", wall_loop / useful_l * 1e6,
         f"{tps_loop:.1f}")
    emit("serving_engine_occupancy", None, f"{occ:.3f}")
    emit("serving_engine_p50_token_latency", float(np.percentile(lat, 50)) * 1e6)
    emit("serving_engine_p99_token_latency", float(np.percentile(lat, 99)) * 1e6)
    _emit_lifecycle("mixed", eng_lat, skip, trace_out)
    _emit_prefill_variants("mixed", eng_lat)
    # packed-prefill TTFT vs. the B=1 chunked baseline (same synced-pass
    # methodology, packing off => one G=1 bucket-padded call per chunk)
    _lat_u, eng_unp, skip_u = _run_engine_latency(
        cfg, params, prompts, max_news, packed_prefill=False)
    ttft_p, _w = _lifecycle_percentiles(eng_lat, skip)
    ttft_u, _w = _lifecycle_percentiles(eng_unp, skip_u)
    for q in (50, 99):
        emit(f"serving_mixed_unpacked_ttft_p{q}",
             float(np.percentile(ttft_u, q)) * 1e6)
    emit("serving_packed_prefill_ttft_speedup", None,
         f"{np.percentile(ttft_u, 50) / np.percentile(ttft_p, 50):.2f}x")
    # host/device split of the synced pass (engine-step timeline)
    host = eng_lat.telemetry.registry.get("engine_step_host_seconds")
    dev = eng_lat.telemetry.registry.get("engine_step_device_seconds")
    if dev.sum + host.sum > 0:
        emit("serving_engine_step_host_fraction", None,
             f"{host.sum / (host.sum + dev.sum):.3f}")
    emit("serving_speedup_vs_legacy_batched", None,
         f"{tps_engine / tps_legacy:.2f}x")
    emit("serving_speedup_vs_legacy_loop", None, f"{tps_engine / tps_loop:.2f}x")


def _main_shared(cfg, params, trace_out=None):
    prompts, max_news, prefix = _workload_shared()
    prompt_tokens = sum(p.shape[0] for p in prompts)

    total_c, wall_c, _occ, hits = _run_engine(
        cfg, params, prompts, max_news, prefix_caching=True, prime=prefix)
    total_n, wall_n, _occ, _h = _run_engine(
        cfg, params, prompts, max_news, prefix_caching=False, prime=prefix)
    tps_cache, tps_nocache = total_c / wall_c, total_n / wall_n
    _lat, eng_lat, skip = _run_engine_latency(
        cfg, params, prompts, max_news, prefix_caching=True, prime=prefix)

    emit("serving_prefix_cache_tokens_per_s", wall_c / total_c * 1e6,
         f"{tps_cache:.1f}")
    emit("serving_prefix_nocache_tokens_per_s", wall_n / total_n * 1e6,
         f"{tps_nocache:.1f}")
    emit("serving_prefix_cache_hit_rate", None,
         f"{hits / prompt_tokens:.3f}")
    emit("serving_prefill_tokens_saved", None, str(int(hits)))
    emit("serving_prefix_cache_speedup", None,
         f"{tps_cache / tps_nocache:.2f}x")
    _emit_lifecycle("shared", eng_lat, skip, trace_out)
    _emit_prefill_variants("shared", eng_lat)


def _main_family(family):
    """One model family through the engine: tokens/s, per-slot state memory
    (from the family's providers), and peak block-pool utilization."""
    cfg = _family_cfg(family)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(block_size=8, num_blocks=128, max_blocks_per_seq=16,
                        max_slots=MAX_SLOTS, prefill_chunk=16,
                        prefills_per_step=2)
    prompts, max_news = _workload(n=16, seed=4)

    def run():
        eng = Engine(cfg, params, ecfg)
        for p, mn in zip(prompts, max_news):
            eng.add_request(p, mn)
        peak = 0.0
        t0 = time.perf_counter()
        while eng.scheduler.has_work:
            eng.step()
            peak = max(peak, eng.block_pool.utilization)
        outs = eng.drain()
        wall = time.perf_counter() - t0
        return eng, sum(o.shape[0] for o in outs.values()), wall, peak

    run()                                          # warmup / compile
    eng, total, wall, peak = run()

    # per-slot state budget at the workload's worst-case context length
    worst = max(p.shape[0] + m for p, m in zip(prompts, max_news))
    mem = SP.state_memory_per_slot(cfg, eng.providers, worst)
    emit(f"serving_family_{family}_tokens_per_s", wall / total * 1e6,
         f"{total / wall:.1f}")
    emit(f"serving_family_{family}_state_kb_per_slot", None,
         f"{mem / 1024:.1f}")
    emit(f"serving_family_{family}_peak_pool_utilization", None,
         f"{peak:.3f}")
    # distinct compiled step variants the run dispatched — a fixed set
    # (decode + the declared AOT prefill buckets [+ reset_slot for
    # recurrent kinds]); growth here is serving-time recompilation
    emit(f"serving_family_{family}_compiled_step_variants", None,
         str(eng.telemetry.recompiles.total))
    _emit_prefill_variants(f"family_{family}", eng)


def main(workload: str = "both", config_family: str = None, trace_out=None):
    if workload not in ("mixed", "shared", "both", "none"):
        raise ValueError(f"unknown workload {workload!r}")
    if workload != "none":
        cfg = _cfg()
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        if workload in ("mixed", "both"):
            _main_mixed(cfg, params, trace_out)
        if workload in ("shared", "both"):
            _main_shared(cfg, params, trace_out)
    if config_family:
        fams = FAMILIES if config_family == "all" else (config_family,)
        for fam in fams:
            _main_family(fam)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("mixed", "shared", "both", "none"),
                    default="both")
    ap.add_argument("--config-family",
                    choices=FAMILIES + ("all",), default=None,
                    help="also run the per-family state-provider sweep")
    ap.add_argument("--trace-out", default=None, metavar="PREFIX",
                    help="write each workload's synced-pass event log to "
                         "PREFIX.<workload>.jsonl (replay via "
                         "repro.serving.telemetry.replay_jsonl)")
    args = ap.parse_args()
    main(args.workload, args.config_family, args.trace_out)
