"""Serving throughput: continuous-batching engine vs legacy static batch,
plus prefix caching on a shared-prefix workload.

Workload `mixed` — chat-shaped mixed lengths (short prompts, skewed
generation budgets, 3x more requests than decode slots) — the regime where
static batching collapses: every batch pads to its longest prompt AND
decodes for its longest budget while finished rows burn compute.

  * legacy — successive `serve.generate` calls over static batches of
    max_slots requests (FCFS, left-padded, max_new = batch max). This is the
    STRONG baseline: it already uses the one-shot batched prefill; the
    seed's token-by-token prefill loop is strictly slower.
  * engine — the same requests through `Engine.step()` with chunked prefill
    and continuous batching.

Workload `shared` — every request repeats a common system-prompt prefix
(chat template / few-shot header) plus a short unique suffix. The engine is
run with prefix caching ON vs OFF (cache primed by one untimed request in
both modes so the comparison is steady-state); rows report cache hit rate,
prefill tokens saved, and the on/off speedup.

Per-family mode (`--config-family full|sliding|ssm|hybrid|all`) runs a
chat-shaped workload through the engine for that model family's state
providers and reports tokens/s, per-slot sequence-state memory (the
provider's per-kind cost: paged KV for full, ring-capped KV for sliding,
O(1) slabs for ssm, the mix for hybrid), and peak block-pool utilization.

Rows: tokens/s, engine decode-batch occupancy, p50/p99 per-token latency
(wall time of the engine step that emitted each token, measured in a
separate synced pass so async dispatch can't hide compute), TTFT and
queue-wait p50/p99 per workload (derived from the engine's request-lifecycle
telemetry in the same synced pass, warmup/prime requests excluded), the
telemetry-overhead check (tokens/s with telemetry off vs on), and the
prefix-cache metrics. Packed-prefill rows: TTFT under packing vs the B=1
chunked baseline (`serving_mixed_unpacked_ttft_*`,
`serving_packed_prefill_ttft_speedup`), per-(chunk x segments) bucket
dispatch counts, and `serving_*_prefill_variants` — prefill trace keys seen
vs declared AOT buckets, where "new=0" certifies the warmup compiled every
variant steady-state serving dispatches. The per-family sweep also reports
the total number of distinct compiled step variants (recompile tracker).

Workload `oversub` — the open-loop overload study (ROADMAP item 2): Poisson
arrivals at 2x the engine's decode capacity with heavy-tailed prompt/output
lengths and a priority mix (`repro.serving.workloads.open_loop_arrivals`),
replayed through BOTH schedulers — optimistic admission + victim preemption
(`OversubConfig`) vs. conservative up-front full reservation. Rows: goodput
(completed tokens/s) for each, the goodput ratio (headline number in the
deterministic step domain — tokens per fixed-shape engine step — with the
noisier wall-clock ratio alongside), preemption/resume rates, and p99
TTFT/TPOT from a synced pass of the optimistic engine. This is the
tail-latency-under-oversubscription measurement the paper's concurrency
analysis calls for: the mean survives overload, the p99 is what collapses.

Speculation rows (`--spec` / `benchmarks/run.py --serving-spec`): the
decode-heavy `spec_workload` through every family with speculative decoding
off vs on. The on-runs draft with a ReplayDrafter fed the off-run's own
greedy outputs — a perfectly aligned draft source — so the speedup row is
the multi-query verify path's CEILING (acceptance ~1, k tokens per step);
the separate n-gram row reports the model-dependent acceptance of the
self-drafting prompt-lookahead. Greedy outputs are asserted bit-identical
on/off inside the bench, and each on-run reports its verify variant count
(must stay 1: the AOT-warmed shape).

Quantized-KV rows (`--kv-quant` / `benchmarks/run.py --serving-kv-quant`):
per KV-holding family (full / sliding / hybrid), engine tokens/s and
per-slot state memory with the paged pools stored fp32 vs int8 + per-vector
scales (`EngineConfig.kv_quant`); a kernel-isolation row timing the paged
decode kernel on identical pool contents fp32 vs int8 (the in-kernel
dequant-multiply overhead); and pool-capacity rows that hold the pool BYTE
budget fixed and report peak resident sequences on the mixed and
shared-prefix workloads — the memory win the quantization buys back as
batch capacity. Each quant run asserts its decode variant count stayed at
the single AOT-warmed shape.

`main(workload=...)` accepts "mixed" | "shared" | "oversub" | "both" (all
three); `benchmarks/run.py --serving-workload` passes it through
(`--serving-family` likewise forwards the family sweep, `--serving-seed`
the workload seed). `--trace-out PREFIX` writes each workload's synced-pass
event log to `PREFIX.<workload>.jsonl` — replayable into per-request
TTFT/decode timelines via `repro.serving.telemetry.replay_jsonl`.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import ModelConfig
from repro.models import state_providers as SP
from repro.models import transformer as T
from repro.serving import serve
from repro.serving import workloads as W
from repro.serving.engine import (Engine, EngineConfig, KVQuantConfig,
                                  OversubConfig, ReplayDrafter, SpecConfig)

FAMILIES = ("full", "sliding", "ssm", "hybrid")


def _cfg():
    return ModelConfig(name="serving-bench", family="dense", num_layers=2,
                       d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
                       d_ff=512, vocab_size=256, loss_chunk=64, attn_chunk=128,
                       remat=False, dtype="float32")


def _family_cfg(family):
    base = dict(num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
                head_dim=64, d_ff=512, vocab_size=256, loss_chunk=64,
                attn_chunk=128, remat=False, dtype="float32")
    if family == "full":
        return ModelConfig(name="sb-full", family="dense", **base)
    if family == "sliding":
        return ModelConfig(name="sb-sliding", family="dense",
                           attention_type="sliding", window_size=32, **base)
    if family == "ssm":
        return ModelConfig(name="sb-ssm", family="ssm", ssm_type="rwkv6",
                           ssm_head_dim=64, **base)
    if family == "hybrid":
        return ModelConfig(name="sb-hybrid", family="hybrid",
                           hybrid_ssm_per_attn=1, ssm_state_dim=16,
                           ssm_head_dim=64, **base)
    raise ValueError(f"unknown family {family!r}")


MAX_SLOTS = 8


def _fresh_engine(cfg, params, prompts, *, prefix_caching=True, prime=None,
                  telemetry=True, step_timing=False, packed_prefill=True):
    eng = Engine(cfg, params, EngineConfig(
        block_size=16, num_blocks=256, max_blocks_per_seq=8,
        max_slots=MAX_SLOTS, prefill_chunk=32, prefills_per_step=4,
        prefix_caching=prefix_caching, telemetry=telemetry,
        step_timing=step_timing, packed_prefill=packed_prefill))
    # warmup: compile decode once on a throwaway request (every prefill
    # bucket is already AOT-compiled at engine construction)
    skip = {eng.add_request(prompts[0][:4], 2)}
    eng.drain()
    if prime is not None:
        # populate the prefix index (no-op with caching off; run in both
        # modes so the timed region does identical request work)
        skip.add(eng.add_request(prime, 1))
        eng.drain()
    return eng, skip


@dataclasses.dataclass
class EngineRun:
    """One engine measurement pass. `latencies` is None for throughput
    runs (free-running steps) and a per-token wall-time array for synced
    runs (`collect_latency=True`)."""
    tokens: int
    wall: float
    occupancy: float
    prefix_hits: int
    latencies: object
    engine: object
    skip: set


def _run_engine(cfg, params, prompts, max_news, *, prefix_caching=True,
                prime=None, telemetry=True, packed_prefill=True,
                collect_latency=False) -> EngineRun:
    """One driver for both measurement modes. Throughput pass
    (`collect_latency=False`): free-running steps, one sync at the end, so
    the host-ahead pipeline is measured. Latency pass: `step_timing=True`
    and a block on each step's emitted tokens, so per-step wall time — and
    the engine's own request-lifecycle timestamps (TTFT, queue wait) — are
    device-completion times, not async dispatch. Warmup and cache-priming
    tokens/steps are excluded from every reported number."""
    eng, skip = _fresh_engine(cfg, params, prompts,
                              prefix_caching=prefix_caching, prime=prime,
                              telemetry=telemetry,
                              step_timing=collect_latency,
                              packed_prefill=packed_prefill)
    warm = dict(eng.stats)
    for p, mn in zip(prompts, max_news):
        eng.add_request(p, mn)
    lat = [] if collect_latency else None
    t0 = time.perf_counter()
    if collect_latency:
        while eng.scheduler.has_work:
            s = time.perf_counter()
            emitted = eng.step()
            jax.block_until_ready(eng.next_tok)
            lat.extend([time.perf_counter() - s] * len(emitted))
    outs = eng.drain()                             # materializes every token
    wall = time.perf_counter() - t0
    total = sum(o.shape[0] for rid, o in outs.items() if rid not in skip)
    occ = ((eng.stats["occupancy_sum"] - warm["occupancy_sum"])
           / max(eng.stats["decode_steps"] - warm["decode_steps"], 1))
    hits = eng.stats["prefix_hit_tokens"] - warm["prefix_hit_tokens"]
    return EngineRun(total, wall, occ, hits,
                     None if lat is None else np.asarray(lat), eng, skip)


def _lifecycle_percentiles(eng, skip):
    """Per-request TTFT and queue-wait arrays from the engine's telemetry,
    excluding warmup/prime requests."""
    ttfts, waits = [], []
    for rid in eng.requests:
        if rid in skip:
            continue
        tl = eng.telemetry.request_timeline(rid)
        if tl["ttft"] is not None:
            ttfts.append(tl["ttft"])
        if tl["queue_wait"] is not None:
            waits.append(tl["queue_wait"])
    return np.asarray(ttfts), np.asarray(waits)


def _emit_lifecycle(tag, eng, skip, trace_out=None):
    ttfts, waits = _lifecycle_percentiles(eng, skip)
    for name, arr in ((f"serving_{tag}_ttft", ttfts),
                      (f"serving_{tag}_queue_wait", waits)):
        for q in (50, 99):
            emit(f"{name}_p{q}", float(np.percentile(arr, q)) * 1e6)
    if trace_out:
        path = f"{trace_out}.{tag}.jsonl"
        n = eng.telemetry.export_jsonl(path)
        emit(f"serving_{tag}_trace_events", None, f"{n}@{path}")


def _emit_prefill_variants(tag, eng):
    """Prefill trace keys seen vs. declared buckets (new must be 0 — the
    AOT warmup contract) plus per-bucket dispatch counts."""
    declared = len(eng.prefill_grid)
    seen = eng.telemetry.recompiles.unique("prefill")
    emit(f"serving_{tag}_prefill_variants", None,
         f"{seen}/{declared} declared (new={seen - declared})")
    for (c, g), n in sorted(eng.bucket_dispatches().items()):
        if n:
            emit(f"serving_{tag}_prefill_bucket_c{c}g{g}_dispatches", None,
                 str(n))


def _legacy_once(cfg, params, prompts, max_news):
    done = 0
    for i in range(0, len(prompts), MAX_SLOTS):
        bp, bn = prompts[i:i + MAX_SLOTS], max_news[i:i + MAX_SLOTS]
        S = max(p.shape[0] for p in bp)
        batch = np.zeros((len(bp), S), np.int32)
        for j, p in enumerate(bp):
            batch[j, S - p.shape[0]:] = p          # left-pad: keep tail intact
        jax.block_until_ready(serve.generate(
            cfg, params, jnp.asarray(batch), max_new=max(bn), temperature=0.0))
        done += sum(bn)                             # tokens anyone asked for
    return done


def _run_legacy(cfg, params, prompts, max_news):
    _legacy_once(cfg, params, prompts, max_news)    # warmup
    t0 = time.perf_counter()
    useful = _legacy_once(cfg, params, prompts, max_news)
    wall = time.perf_counter() - t0
    return useful, wall


def _run_legacy_loop(cfg, params, prompts, max_news):
    """The seed's serving loop: token-by-token sequential prefill (kept as
    `prefill_mode='loop'`), one static batch at a time."""
    def once():
        done = 0
        for i in range(0, len(prompts), MAX_SLOTS):
            bp, bn = prompts[i:i + MAX_SLOTS], max_news[i:i + MAX_SLOTS]
            S = max(p.shape[0] for p in bp)
            batch = np.zeros((len(bp), S), np.int32)
            for j, p in enumerate(bp):
                batch[j, S - p.shape[0]:] = p
            jax.block_until_ready(serve.generate(
                cfg, params, jnp.asarray(batch), max_new=max(bn),
                temperature=0.0, prefill_mode="loop"))
            done += sum(bn)
        return done
    once()                                           # warmup
    t0 = time.perf_counter()
    useful = once()
    wall = time.perf_counter() - t0
    return useful, wall


def _main_mixed(cfg, params, trace_out=None, seed=0):
    prompts, max_news = W.mixed_workload(seed=seed)

    thr = _run_engine(cfg, params, prompts, max_news)
    total, wall, occ = thr.tokens, thr.wall, thr.occupancy
    tps_engine = total / wall
    off = _run_engine(cfg, params, prompts, max_news, telemetry=False)
    total_o, wall_o = off.tokens, off.wall
    tps_off = total_o / wall_o
    useful, wall_legacy = _run_legacy(cfg, params, prompts, max_news)
    tps_legacy = useful / wall_legacy
    useful_l, wall_loop = _run_legacy_loop(cfg, params, prompts, max_news)
    tps_loop = useful_l / wall_loop
    sync = _run_engine(cfg, params, prompts, max_news, collect_latency=True)
    lat, eng_lat, skip = sync.latencies, sync.engine, sync.skip

    emit("serving_engine_tokens_per_s", wall / total * 1e6, f"{tps_engine:.1f}")
    emit("serving_telemetry_off_tokens_per_s", wall_o / total_o * 1e6,
         f"{tps_off:.1f}")
    emit("serving_telemetry_overhead", None,
         f"{wall / total / (wall_o / total_o):.3f}x")
    emit("serving_legacy_batched_tokens_per_s", wall_legacy / useful * 1e6,
         f"{tps_legacy:.1f}")
    emit("serving_legacy_loop_tokens_per_s", wall_loop / useful_l * 1e6,
         f"{tps_loop:.1f}")
    emit("serving_engine_occupancy", None, f"{occ:.3f}")
    emit("serving_engine_p50_token_latency", float(np.percentile(lat, 50)) * 1e6)
    emit("serving_engine_p99_token_latency", float(np.percentile(lat, 99)) * 1e6)
    _emit_lifecycle("mixed", eng_lat, skip, trace_out)
    _emit_prefill_variants("mixed", eng_lat)
    # packed-prefill TTFT vs. the B=1 chunked baseline (same synced-pass
    # methodology, packing off => one G=1 bucket-padded call per chunk)
    unp = _run_engine(cfg, params, prompts, max_news, packed_prefill=False,
                      collect_latency=True)
    eng_unp, skip_u = unp.engine, unp.skip
    ttft_p, _w = _lifecycle_percentiles(eng_lat, skip)
    ttft_u, _w = _lifecycle_percentiles(eng_unp, skip_u)
    for q in (50, 99):
        emit(f"serving_mixed_unpacked_ttft_p{q}",
             float(np.percentile(ttft_u, q)) * 1e6)
    emit("serving_packed_prefill_ttft_speedup", None,
         f"{np.percentile(ttft_u, 50) / np.percentile(ttft_p, 50):.2f}x")
    # host/device split of the synced pass (engine-step timeline)
    host = eng_lat.telemetry.registry.get("engine_step_host_seconds")
    dev = eng_lat.telemetry.registry.get("engine_step_device_seconds")
    if dev.sum + host.sum > 0:
        emit("serving_engine_step_host_fraction", None,
             f"{host.sum / (host.sum + dev.sum):.3f}")
    emit("serving_speedup_vs_legacy_batched", None,
         f"{tps_engine / tps_legacy:.2f}x")
    emit("serving_speedup_vs_legacy_loop", None, f"{tps_engine / tps_loop:.2f}x")


def _main_shared(cfg, params, trace_out=None, seed=0):
    prompts, max_news, prefix = W.shared_prefix_workload(seed=seed)
    prompt_tokens = sum(p.shape[0] for p in prompts)

    cache = _run_engine(cfg, params, prompts, max_news, prefix_caching=True,
                        prime=prefix)
    total_c, wall_c, hits = cache.tokens, cache.wall, cache.prefix_hits
    nocache = _run_engine(cfg, params, prompts, max_news, prefix_caching=False,
                          prime=prefix)
    total_n, wall_n = nocache.tokens, nocache.wall
    tps_cache, tps_nocache = total_c / wall_c, total_n / wall_n
    sync = _run_engine(cfg, params, prompts, max_news, prefix_caching=True,
                       prime=prefix, collect_latency=True)
    eng_lat, skip = sync.engine, sync.skip

    emit("serving_prefix_cache_tokens_per_s", wall_c / total_c * 1e6,
         f"{tps_cache:.1f}")
    emit("serving_prefix_nocache_tokens_per_s", wall_n / total_n * 1e6,
         f"{tps_nocache:.1f}")
    emit("serving_prefix_cache_hit_rate", None,
         f"{hits / prompt_tokens:.3f}")
    emit("serving_prefill_tokens_saved", None, str(int(hits)))
    emit("serving_prefix_cache_speedup", None,
         f"{tps_cache / tps_nocache:.2f}x")
    _emit_lifecycle("shared", eng_lat, skip, trace_out)
    _emit_prefill_variants("shared", eng_lat)


def _main_family(family, seed=0):
    """One model family through the engine: tokens/s, per-slot state memory
    (from the family's providers), and peak block-pool utilization."""
    cfg = _family_cfg(family)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(block_size=8, num_blocks=128, max_blocks_per_seq=16,
                        max_slots=MAX_SLOTS, prefill_chunk=16,
                        prefills_per_step=2)
    prompts, max_news = W.mixed_workload(n=16, seed=seed + 4)

    def run():
        eng = Engine(cfg, params, ecfg)
        for p, mn in zip(prompts, max_news):
            eng.add_request(p, mn)
        peak = 0.0
        t0 = time.perf_counter()
        while eng.scheduler.has_work:
            eng.step()
            peak = max(peak, eng.block_pool.utilization)
        outs = eng.drain()
        wall = time.perf_counter() - t0
        return eng, sum(o.shape[0] for o in outs.values()), wall, peak

    run()                                          # warmup / compile
    eng, total, wall, peak = run()

    # per-slot state budget at the workload's worst-case context length
    worst = max(p.shape[0] + m for p, m in zip(prompts, max_news))
    mem = SP.state_memory_per_slot(cfg, eng.providers, worst)
    emit(f"serving_family_{family}_tokens_per_s", wall / total * 1e6,
         f"{total / wall:.1f}")
    emit(f"serving_family_{family}_state_kb_per_slot", None,
         f"{mem / 1024:.1f}")
    emit(f"serving_family_{family}_peak_pool_utilization", None,
         f"{peak:.3f}")
    # distinct compiled step variants the run dispatched — a fixed set
    # (decode + the declared AOT prefill buckets [+ reset_slot for
    # recurrent kinds]); growth here is serving-time recompilation
    emit(f"serving_family_{family}_compiled_step_variants", None,
         str(eng.telemetry.recompiles.total))
    _emit_prefill_variants(f"family_{family}", eng)


OV_BLOCKS = 24       # tight pool: 384 KV tokens for up to 8 x 256-token seqs


def _ov_cfg():
    """The overload study runs a larger model than the closed-loop rows:
    the goodput gap between schedulers is a decode-occupancy gap, visible in
    wall time only when the per-step model compute dominates the per-token
    host bookkeeping both engines share."""
    return ModelConfig(name="serving-ov", family="dense", num_layers=4,
                       d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
                       d_ff=1024, vocab_size=256, loss_chunk=64,
                       attn_chunk=128, remat=False, dtype="float32")


def _ov_ecfg(oversub):
    """Engine config for the overload study. The pool is deliberately small
    relative to worst-case demand (8 slots x 16 blocks = 128 >> 24 blocks)
    and to the mean full reservation (~5 blocks x 8 slots), so up-front
    reservation is pool-bound at ~4-5 concurrent requests while optimistic
    admission keeps all 8 slots decoding and preempts on actual exhaustion."""
    return EngineConfig(block_size=16, num_blocks=OV_BLOCKS,
                        max_blocks_per_seq=16, max_slots=MAX_SLOTS,
                        prefill_chunk=32, prefills_per_step=4,
                        oversub=oversub)


def _run_open_loop(cfg, params, arrivals, ecfg, *, synced=False):
    """Replay an open-loop arrival trace: admit every arrival whose step has
    come, step the engine, repeat. Arrivals never wait for completions —
    under overload the waiting queue grows and the scheduler must cope.
    Returns (tokens, wall, steps, engine, skip)."""
    if synced:
        ecfg = dataclasses.replace(ecfg, step_timing=True)
    eng = Engine(cfg, params, ecfg)
    skip = {eng.add_request(arrivals[0].prompt[:4], 2)}   # decode warmup
    eng.drain()
    i, step = 0, 0
    t0 = time.perf_counter()
    while i < len(arrivals) or eng.scheduler.has_work:
        while i < len(arrivals) and arrivals[i].step <= step:
            a = arrivals[i]
            eng.add_request(a.prompt, a.max_new, priority=a.priority)
            i += 1
        if eng.scheduler.has_work:
            eng.step()
            if synced:
                jax.block_until_ready(eng.next_tok)
            step += 1
        else:
            step = arrivals[i].step                        # idle: fast-forward
    outs = eng.drain()
    wall = time.perf_counter() - t0
    tokens = sum(o.shape[0] for rid, o in outs.items() if rid not in skip)
    return tokens, wall, step, eng, skip


def _main_oversub(trace_out=None, seed=0):
    cfg = _ov_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    arrivals = W.open_loop_arrivals(
        48, seed=seed, overload=2.0, max_slots=MAX_SLOTS, prompt_mean=12.0,
        prompt_max=32, out_mean=64.0, out_max=224)
    n = len(arrivals)

    tok_o, wall_o, steps_o, eng_o, _s = _run_open_loop(
        cfg, params, arrivals, _ov_ecfg(OversubConfig()))
    tok_f, wall_f, steps_f, eng_f, _s = _run_open_loop(
        cfg, params, arrivals, _ov_ecfg(None))
    gp_o, gp_f = tok_o / wall_o, tok_f / wall_f

    emit("serving_oversub_goodput_tokens_per_s", wall_o / tok_o * 1e6,
         f"{gp_o:.1f}")
    emit("serving_fullres_goodput_tokens_per_s", wall_f / tok_f * 1e6,
         f"{gp_f:.1f}")
    # same trace, same total work — the gap is pure scheduling, so the
    # headline ratio is measured in the step domain (engine steps have fixed
    # shapes and near-constant cost, and the count is deterministic given
    # (seed, params)); the wall-clock view rides along in the derived text
    emit("serving_oversub_goodput_ratio", None,
         f"{(tok_o / steps_o) / (tok_f / steps_f):.2f}x "
         f"(steps; wall {gp_o / gp_f:.2f}x)")
    emit("serving_oversub_tokens_per_step", None, f"{tok_o / steps_o:.2f}")
    emit("serving_fullres_tokens_per_step", None, f"{tok_f / steps_f:.2f}")
    st = eng_o.stats
    emit("serving_oversub_preempts_per_request", None,
         f"{st['preemptions'] / n:.3f}")
    emit("serving_oversub_resumes", None, str(st["resumes"]))
    emit("serving_oversub_block_appends", None, str(st["block_appends"]))

    # tail latencies from a synced pass of the optimistic engine: per-step
    # blocking makes every lifecycle timestamp a device-completion time
    _t, _w, _n, eng_s, skip_s = _run_open_loop(
        cfg, params, arrivals, _ov_ecfg(OversubConfig()), synced=True)
    _emit_lifecycle("oversub", eng_s, skip_s, trace_out)
    tpots = []
    for rid in eng_s.requests:
        if rid in skip_s:
            continue
        tl = eng_s.telemetry.request_timeline(rid)
        if tl["first_token"] is not None and tl["decode_tokens"]:
            toks = [tl["first_token"]] + tl["decode_tokens"]
            tpots.append((toks[-1] - toks[0]) / (len(toks) - 1))
    for q in (50, 99):
        emit(f"serving_oversub_tpot_p{q}",
             float(np.percentile(tpots, q)) * 1e6)


SPEC_K = 8           # verify width for the speculation rows


def _spec_ecfg(spec):
    return EngineConfig(block_size=16, num_blocks=256, max_blocks_per_seq=8,
                        max_slots=MAX_SLOTS, prefill_chunk=32,
                        prefills_per_step=4, spec=spec)


def _run_spec(cfg, params, prompts, max_news, spec, streams=None):
    """One measured pass (second of two; the first warms the compile
    caches). With ``streams`` (one expected prompt++output stream per
    request, submit order) the spec config's ReplayDrafter is fed the true
    continuations — the high-acceptance limit. Returns (outputs by submit
    order, wall seconds, engine)."""
    def once():
        eng = Engine(cfg, params, _spec_ecfg(spec))
        rids = [eng.add_request(p, mn) for p, mn in zip(prompts, max_news)]
        if streams is not None:
            for rid, s in zip(rids, streams):
                eng.drafter.remember(rid, s)
        t0 = time.perf_counter()
        outs = eng.drain()
        wall = time.perf_counter() - t0
        return [outs[r] for r in rids], wall, eng
    once()
    return once()


def _main_spec(trace_out=None, seed=0):
    """Speculative decoding rows: per family, wall tokens/s with speculation
    off vs on (ReplayDrafter — a perfectly aligned draft source, so the row
    measures the verify path's ceiling), acceptance rate, and tokens per
    verify step; plus the self-drafting n-gram row on the full-attention
    family (model-dependent acceptance). Greedy outputs are bit-identical
    on/off — asserted here, not just claimed."""
    prompts, max_news = W.spec_workload(seed=seed)
    for fam in FAMILIES:
        cfg = _family_cfg(fam)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        outs_off, wall_off, _e = _run_spec(cfg, params, prompts, max_news,
                                           None)
        # the off run's greedy outputs ARE the true continuations (greedy is
        # bit-identical on/off): replay them as drafts to measure the
        # high-acceptance limit of the verify path
        streams = [np.concatenate([p, o]) for p, o in zip(prompts, outs_off)]
        spec = SpecConfig(k=SPEC_K, drafter=ReplayDrafter())
        outs_on, wall_on, eng = _run_spec(cfg, params, prompts, max_news,
                                          spec, streams=streams)
        for a, b in zip(outs_off, outs_on):
            np.testing.assert_array_equal(a, b)
        total = sum(o.shape[0] for o in outs_on)
        snap = eng.telemetry.registry.snapshot()
        drafted = snap["engine_draft_tokens_total"]
        accepted = snap["engine_accepted_tokens_total"]
        vsteps = snap["engine_verify_steps_total"]
        emit(f"serving_spec_{fam}_off_tokens_per_s", wall_off / total * 1e6,
             f"{total / wall_off:.1f}")
        emit(f"serving_spec_{fam}_on_tokens_per_s", wall_on / total * 1e6,
             f"{total / wall_on:.1f}")
        emit(f"serving_spec_{fam}_speedup", None,
             f"{wall_off / wall_on:.2f}x")
        emit(f"serving_spec_{fam}_acceptance", None,
             f"{accepted / max(drafted, 1):.3f}")
        emit(f"serving_spec_{fam}_tokens_per_verify_step", None,
             f"{total / max(vsteps, 1):.2f}")
        emit(f"serving_spec_{fam}_verify_variants", None,
             str(eng.telemetry.recompiles.unique("verify")))

    # self-drafting n-gram lookahead on the full-attention family: no
    # oracle, acceptance is whatever the model's own stream offers
    cfg = _family_cfg("full")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    outs_off, wall_off, _e = _run_spec(cfg, params, prompts, max_news, None)
    outs_on, wall_on, eng = _run_spec(cfg, params, prompts, max_news,
                                      SpecConfig(k=4, drafter="ngram"))
    for a, b in zip(outs_off, outs_on):
        np.testing.assert_array_equal(a, b)
    snap = eng.telemetry.registry.snapshot()
    rate = (snap["engine_accepted_tokens_total"]
            / max(snap["engine_draft_tokens_total"], 1))
    emit("serving_spec_ngram_speedup", None, f"{wall_off / wall_on:.2f}x")
    emit("serving_spec_ngram_acceptance", None, f"{rate:.3f}")


KVQ_FAMILIES = ("full", "sliding", "hybrid")   # ssm holds no KV to quantize
KVQ_CAP_BLOCKS = 32  # fixed pool byte budget for the capacity rows (fp32)


def _kvq_ecfg(kv_quant, *, num_blocks=128, max_slots=MAX_SLOTS):
    return EngineConfig(block_size=8, num_blocks=num_blocks,
                        max_blocks_per_seq=16, max_slots=max_slots,
                        prefill_chunk=16, prefills_per_step=2,
                        kv_quant=kv_quant)


def _run_kvq(cfg, params, prompts, max_news, ecfg):
    """Two passes (first warms the compile caches); returns the measured
    pass's (engine, tokens, wall, peak resident sequences)."""
    def once():
        eng = Engine(cfg, params, ecfg)
        for p, mn in zip(prompts, max_news):
            eng.add_request(p, mn)
        peak = 0
        t0 = time.perf_counter()
        while eng.scheduler.has_work:
            eng.step()
            peak = max(peak, len(eng.scheduler.running))
        outs = eng.drain()
        wall = time.perf_counter() - t0
        return eng, sum(o.shape[0] for o in outs.values()), wall, peak
    once()
    return once()


def _kvq_kernel_overhead(mode, kvq_bits=8, iters=20):
    """Direct kernel timing: the paged decode kernel on the same pool
    contents, fp32 vs int8+scales — the dequant-multiply overhead in
    isolation (full mode for the dense family, ring mode for sliding)."""
    from repro.kernels.paged_attention import ops as PA
    from repro.kernels.quantize import quantize_kv
    B, Hq, Hkv, hd, bs, N, P = 8, 4, 2, 64, 16, 64, 8
    key = jax.random.PRNGKey(0)
    kk, kv_, kq = jax.random.split(key, 3)
    k_pool = jax.random.normal(kk, (N, bs, Hkv, hd), jnp.float32)
    v_pool = jax.random.normal(kv_, (N, bs, Hkv, hd), jnp.float32)
    q = jax.random.normal(kq, (B, Hq, hd), jnp.float32)
    tables = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P) % N
    lens = jnp.full((B,), P * bs, jnp.int32)
    kw = {}
    if mode == "ring":
        kw = dict(window=bs * (P - 1), positions=lens - 1, ring_pages=P)
    qk, sk = quantize_kv(k_pool)
    qv, sv = quantize_kv(v_pool)

    def time_call(fn):
        jax.block_until_ready(fn())                 # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn())
        return (time.perf_counter() - t0) / iters

    t_f32 = time_call(lambda: PA.paged_attention(q, k_pool, v_pool, tables,
                                                 lens, **kw))
    t_int8 = time_call(lambda: PA.paged_attention(q, qk, qv, tables, lens,
                                                  k_scale=sk, v_scale=sv,
                                                  **kw))
    return t_f32, t_int8


def _main_kv_quant(seed=0):
    """Quantized paged KV rows (ROADMAP item 4): per family, engine tokens/s
    and per-slot state memory with the pools fp32 vs int8+per-vector scales;
    the dequant-overhead row times the paged kernel alone on identical pool
    contents; the capacity rows hold the pool BYTE budget fixed (the fp32
    row's pool, ~`KVQ_CAP_BLOCKS` blocks) and report peak resident
    sequences on the mixed and shared-prefix workloads — the number int8
    must lift >=1.8x. Decode variant counts are asserted flat (==1): quant
    changes the traced pool pytree, so the warmup must have compiled it."""
    kvq = KVQuantConfig()
    prompts, max_news = W.mixed_workload(n=16, seed=seed + 4)
    worst = max(p.shape[0] + m for p, m in zip(prompts, max_news))
    for fam in KVQ_FAMILIES:
        cfg = _family_cfg(fam)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        tps, kb = {}, {}
        for tag, q in (("fp32", None), ("int8", kvq)):
            eng, total, wall, _peak = _run_kvq(cfg, params, prompts,
                                               max_news, _kvq_ecfg(q))
            tps[tag] = total / wall
            kb[tag] = SP.state_memory_per_slot(cfg, eng.providers, worst)
            if q is not None:
                dv = eng.telemetry.recompiles.unique("decode")
                assert dv == 1, f"{fam}: {dv} decode variants with quant on"
                snap = eng.telemetry.registry.snapshot()
                emit(f"serving_kv_quant_{fam}_bytes_saved", None,
                     str(int(snap["kv_quant_bytes_saved_total"])))
                emit(f"serving_kv_quant_{fam}_decode_variants", None,
                     str(dv))
        emit(f"serving_kv_quant_{fam}_fp32_tokens_per_s", None,
             f"{tps['fp32']:.1f}")
        emit(f"serving_kv_quant_{fam}_int8_tokens_per_s",
             1.0 / tps["int8"] * 1e6, f"{tps['int8']:.1f}")
        emit(f"serving_kv_quant_{fam}_tokens_per_s_ratio", None,
             f"{tps['int8'] / tps['fp32']:.2f}x")
        emit(f"serving_kv_quant_{fam}_state_kb_per_slot", None,
             f"{kb['int8'] / 1024:.1f} (fp32 {kb['fp32'] / 1024:.1f}, "
             f"{kb['int8'] / kb['fp32']:.2f}x)")

    # dequant overhead in isolation: kernel wall time on identical contents
    for fam, mode in (("full", "full"), ("sliding", "ring")):
        t_f32, t_int8 = _kvq_kernel_overhead(mode)
        emit(f"serving_kv_quant_{fam}_kernel_overhead", None,
             f"{t_int8 / t_f32:.2f}x ({t_int8 * 1e6:.0f}us vs "
             f"{t_f32 * 1e6:.0f}us)")

    # pool capacity at a fixed byte budget: the fp32 pool's bytes buy
    # ~3.76x as many int8 blocks (2*hkv*hd*4 -> 2*hkv*(hd+4) per token)
    cfg = _family_cfg("full")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    blocks_int8 = KVQ_CAP_BLOCKS * (2 * hkv * hd * 4) // (2 * hkv * (hd + 4))
    for wname, (wp, wm) in (
            ("mixed", W.mixed_workload(seed=seed)),
            ("shared", W.shared_prefix_workload(seed=seed)[:2])):
        res = {}
        for tag, q, nb in (("fp32", None, KVQ_CAP_BLOCKS),
                           ("int8", kvq, blocks_int8)):
            _e, _t, _w, peak = _run_kvq(
                cfg, params, wp, wm,
                _kvq_ecfg(q, num_blocks=nb, max_slots=16))
            res[tag] = peak
        emit(f"serving_kv_quant_{wname}_max_resident_fp32", None,
             f"{res['fp32']} ({KVQ_CAP_BLOCKS} blocks)")
        emit(f"serving_kv_quant_{wname}_max_resident_int8", None,
             f"{res['int8']} ({blocks_int8} blocks)")
        emit(f"serving_kv_quant_{wname}_capacity_ratio", None,
             f"{res['int8'] / max(res['fp32'], 1):.2f}x")


def main(workload: str = "both", config_family: str = None, trace_out=None,
         seed: int = 0, spec: bool = False, kv_quant: bool = False):
    if workload not in ("mixed", "shared", "oversub", "both", "none"):
        raise ValueError(f"unknown workload {workload!r}")
    if workload != "none":
        cfg = _cfg()
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        if workload in ("mixed", "both"):
            _main_mixed(cfg, params, trace_out, seed)
        if workload in ("shared", "both"):
            _main_shared(cfg, params, trace_out, seed)
        if workload in ("oversub", "both"):
            _main_oversub(trace_out, seed)
    if spec:
        _main_spec(trace_out, seed)
    if kv_quant:
        _main_kv_quant(seed)
    if config_family:
        fams = FAMILIES if config_family == "all" else (config_family,)
        for fam in fams:
            _main_family(fam, seed)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload",
                    choices=("mixed", "shared", "oversub", "both", "none"),
                    default="both")
    ap.add_argument("--config-family",
                    choices=FAMILIES + ("all",), default=None,
                    help="also run the per-family state-provider sweep")
    ap.add_argument("--spec", action="store_true",
                    help="also run the speculative-decoding rows (per-family "
                         "spec on/off, acceptance, tokens per verify step)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="also run the quantized-KV rows (per-family tokens/s"
                         " and state-KB/slot fp32 vs int8, kernel dequant "
                         "overhead, pool capacity at a fixed byte budget)")
    ap.add_argument("--trace-out", default=None, metavar="PREFIX",
                    help="write each workload's synced-pass event log to "
                         "PREFIX.<workload>.jsonl (replay via "
                         "repro.serving.telemetry.replay_jsonl)")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload-generator seed (arrival trace, lengths)")
    args = ap.parse_args()
    main(args.workload, args.config_family, args.trace_out, args.seed,
         args.spec, args.kv_quant)
