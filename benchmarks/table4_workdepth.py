"""Paper Table 4: asymptotic Work-Depth per layer type, instantiated at
AlexNet-era shapes (derived = W, D, avg parallelism W/D)."""
from benchmarks.common import emit
from repro.core import workdepth as wd


def main():
    N, C, H = 128, 96, 55
    rows = [
        ("fc_y", wd.fully_connected(N, 4096, 4096, "y")),
        ("fc_dw", wd.fully_connected(N, 4096, 4096, "dw")),
        ("fc_dx", wd.fully_connected(N, 4096, 4096, "dx")),
        ("conv_y", wd.conv_direct(N, 227, 227, 3, 96, 11, 11, "y")),
        ("conv_dw", wd.conv_direct(N, 227, 227, 3, 96, 11, 11, "dw")),
        ("pool_y", wd.pooling(N, C, H, H, 3, 3, "y")),
        ("bn_y", wd.batchnorm(N, C, H, H, "y")),
        ("act_y", wd.activation(N, C, H, H, "y")),
        ("attn_y(4k)", wd.attention(8, 4096, 32, 128)),
        ("attn_y(4k,swa)", wd.attention(8, 4096, 32, 128, window=1024)),
    ]
    for name, r in rows:
        emit(f"table4/{name}", None,
             f"W={r.work} D={r.depth} par={r.avg_parallelism:.3e}")


if __name__ == "__main__":
    main()
