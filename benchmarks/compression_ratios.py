"""Paper §6.3 (Table 8 compression rows): quantization + sparsification —
wire compression ratio, roundtrip error, and kernel timing. Reproduces the
Strom-2015 claim that threshold+quantization reaches the 846–2871× range."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.compression import make_compressor
from repro.kernels.quantize import quantize_blocks


def main():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (1 << 20,)) * 0.01    # 1M-element gradient

    for name in ("stochastic_bf16", "int8", "int4", "ternary", "onebit",
                 "topk", "topk_int8"):
        comp = make_compressor(name, frac=0.01)
        fn = jax.jit(lambda x: comp(x, key))
        us, out = time_fn(fn, g)
        rel = float(jnp.linalg.norm(out - g) / jnp.linalg.norm(g))
        emit(f"compression/{name}", us,
             f"ratio={comp.ratio():.1f}x rel_err={rel:.3f}")

    strom = make_compressor("topk_int8", frac=0.0005)
    emit("compression/strom2015_regime", None,
         f"ratio={strom.ratio():.0f}x in_paper_range="
         f"{846 <= strom.ratio() <= 2871}")

    us, _ = time_fn(jax.jit(lambda x: quantize_blocks(x, key)), g)
    emit("compression/pallas_int8_kernel_1M", us, "interpret-mode on CPU")


if __name__ == "__main__":
    main()
