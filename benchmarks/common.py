"""Benchmark helpers: timing + CSV emit (`name,us_per_call,derived`)."""
import time

import jax


def time_fn(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out  # us


def emit(name, us, derived=""):
    print(f"{name},{us if us is None else round(us, 2)},{derived}", flush=True)
