"""Kernel microbenchmarks (§4 layer computation): Pallas kernels in interpret
mode vs their XLA oracles on CPU. Wall times here measure the *oracle* (XLA)
path meaningfully; interpret-mode kernel numbers are correctness artifacts —
real kernel perf requires a TPU (DESIGN.md §5)."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.flash_attention import attention_ref
from repro.kernels.matmul import matmul_ref
from repro.models import attention as A


def main():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (512, 512), jnp.float32)
    b = jax.random.normal(k2, (512, 512), jnp.float32)
    us, _ = time_fn(jax.jit(matmul_ref), a, b)
    flops = 2 * 512 ** 3
    emit("kernels/matmul_ref_512", us, f"gflops={flops / us / 1e3:.2f}")

    B, S, H, hd = 1, 1024, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (B * H, S, hd)) for kk in ks)
    us, _ = time_fn(jax.jit(lambda *t: attention_ref(*t, causal=True)), q, k, v)
    emit("kernels/attention_ref_1k", us, "materialized scores")

    qb, kb, vb = (t.reshape(B, H, S, hd).transpose(0, 2, 1, 3) for t in (q, k, v))
    chunked = jax.jit(lambda q_, k_, v_: A._chunked_attention(
        q_, k_, v_, n_rep=1, scale=hd ** -0.5, chunk=128, window=None))
    us2, _ = time_fn(chunked, qb, kb, vb)
    emit("kernels/attention_chunked_1k", us2,
         f"flash-style XLA path, vs_naive={us / us2:.2f}x")


if __name__ == "__main__":
    main()
