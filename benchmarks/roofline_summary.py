"""Deliverable (g): roofline table from the dry-run sweep artifacts
(results/dryrun/*.json). derived = three terms + dominant + useful-FLOP
ratio per (arch × shape × mesh × plan)."""
import glob
import json
import os

from benchmarks.common import emit


def main():
    files = sorted(glob.glob("results/dryrun/*.json"))
    if not files:
        emit("roofline/none", None, "run `python -m repro.launch.sweep` first")
        return
    for f in files:
        r = json.load(open(f))
        key = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/{r.get('plan','?')}"
        if r["status"] == "skipped":
            emit(key, None, "skipped: " + r["reason"][:60])
            continue
        if r["status"] != "ok":
            emit(key, None, "ERROR")
            continue
        t = r["roofline"]
        emit(key, None,
             f"compute={t['compute_s']:.3f}s memory={t['memory_s']:.3f}s "
             f"collective={t['collective_s']:.3f}s dom={r['dominant']} "
             f"useful={r['useful_flops_ratio']:.2f} fits={r['fits_hbm']}")


if __name__ == "__main__":
    main()
