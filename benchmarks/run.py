"""Benchmark harness (deliverable (d)): one module per paper table/figure.
Prints `name,us_per_call,derived` CSV rows.

`--serving-workload mixed|shared|oversub|both` is passed through to
benchmarks.serving_bench (shared = the prefix-caching comparison, oversub =
the open-loop overload study: optimistic admission + preemption vs full
reservation); the mixed workload's rows include the packed-prefill TTFT
p50/p99 vs the B=1 chunked baseline, the per-(chunk x segments) AOT-bucket
dispatch counts, and the prefill variants seen-vs-declared check (new=0
after warmup). `--serving-family full|sliding|ssm|hybrid|all` adds the
per-family state-provider sweep; `--serving-seed` seeds every serving
workload generator (request lengths, arrival trace);
`--serving-trace-out PREFIX` writes each workload's request-lifecycle event
log to PREFIX.<workload>.jsonl (replayable via
repro.serving.telemetry.replay_jsonl). `--serving-kv-quant` adds the
quantized paged-KV rows: per-family tokens/s and state-KB/slot with the
pools fp32 vs int8+scales, the paged kernel's dequant overhead in
isolation, and peak resident sequences at a fixed pool byte budget."""
import argparse
import sys
import traceback

MODULES = [
    "benchmarks.table3_update_rules",     # Table 3: weight update rules
    "benchmarks.table4_workdepth",        # Table 4: layer W-D
    "benchmarks.table5_networks",         # Table 5 + §3.3.1 LeNet claim
    "benchmarks.table6_conv_algorithms",  # Table 6: conv algorithm W-D
    "benchmarks.fig6_collectives",        # Fig 6 / §2.5: allreduce algorithms
    "benchmarks.fig7_minibatch",          # Fig 7: minibatch-size effect
    "benchmarks.consistency_spectrum",    # §6.1 / Fig 28: staleness spectrum
    "benchmarks.compression_ratios",      # §6.3: quantization/sparsification
    "benchmarks.sec4_conv_measured",      # §4.3: conv algorithms, measured
    "benchmarks.sec64_sec65_meta",        # §6.4 consolidation + §6.5 meta-opt
    "benchmarks.kernels_bench",           # §4: layer computation kernels
    "benchmarks.serving_bench",           # §7 inference: engine vs static batch
    "benchmarks.roofline_summary",        # deliverable (g) roofline table
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--serving-workload",
                    choices=("mixed", "shared", "oversub", "both", "none"),
                    default="both", help="workload(s) for serving_bench")
    ap.add_argument("--serving-family",
                    choices=("full", "sliding", "ssm", "hybrid", "all"),
                    default=None,
                    help="per-family state-provider sweep for serving_bench")
    ap.add_argument("--serving-trace-out", default=None, metavar="PREFIX",
                    help="JSONL request-trace prefix for serving_bench")
    ap.add_argument("--serving-seed", type=int, default=0,
                    help="workload-generator seed for serving_bench")
    ap.add_argument("--serving-spec", action="store_true",
                    help="speculative-decoding rows for serving_bench "
                         "(per-family spec on/off tokens/s, acceptance rate, "
                         "tokens per verify step)")
    ap.add_argument("--serving-kv-quant", action="store_true",
                    help="quantized-KV rows for serving_bench (per-family "
                         "tokens/s and state-KB/slot fp32 vs int8, kernel "
                         "dequant overhead, fixed-budget pool capacity)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        kwargs = ({"workload": args.serving_workload,
                   "config_family": args.serving_family,
                   "trace_out": args.serving_trace_out,
                   "seed": args.serving_seed,
                   "spec": args.serving_spec,
                   "kv_quant": args.serving_kv_quant}
                  if mod_name == "benchmarks.serving_bench" else {})
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main(**kwargs)
        except Exception:
            failures += 1
            print(f"{mod_name},ERROR,", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
