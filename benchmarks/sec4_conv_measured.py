"""§4.3 measured: the four convolution algorithms as runnable JAX code —
wall time on CPU across kernel sizes, exhibiting the paper's claim that the
best algorithm depends on the shape ('no one-size-fits-all')."""
import jax

from benchmarks.common import emit, time_fn
from repro.models import conv as CV


def main():
    key = jax.random.PRNGKey(0)
    N, C, H, K = 4, 16, 32, 16
    for Ky in (3, 5, 7):
        k1, k2 = jax.random.split(jax.random.PRNGKey(Ky))
        x = jax.random.normal(k1, (N, C, H, H))
        w = jax.random.normal(k2, (K, C, Ky, Ky)) * 0.1
        times = {}
        for name, fn in CV.ALGORITHMS.items():
            if name == "winograd" and Ky != 3:
                continue
            jfn = jax.jit(fn)
            us, _ = time_fn(jfn, x, w)
            times[name] = us
        best = min(times, key=times.get)
        for name, us in times.items():
            emit(f"sec4/K={Ky}/{name}", us, f"best={name == best}")


if __name__ == "__main__":
    main()
