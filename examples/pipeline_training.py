"""Survey §5.3: layer-pipeline (GPipe) demo over 4 pipeline stages.

    PYTHONPATH=src python examples/pipeline_training.py

Runs an MLP forward through the microbatch pipeline schedule, verifies it
against the sequential computation, and prints the bubble fraction predicted
by the paper's latency analysis vs the schedule's actual idle slots.
"""
import os
import subprocess
import sys

CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.core.pipeline import pipeline_forward, num_pipeline_rounds
from repro.core.costmodel import pipeline_bubble_fraction

mesh = jax.make_mesh((4,), ("stage",),
                     axis_types=(jax.sharding.AxisType.Auto,))
S, M, mb, dim = 4, 8, 16, 32
key = jax.random.PRNGKey(0)
W = jax.random.normal(key, (S, dim, dim)) * 0.3
b = jax.random.normal(jax.random.PRNGKey(1), (S, dim)) * 0.1

def stage_fn(p, x):
    # p arrives pre-sliced to this stage: w (dim, dim), b (dim,)
    return jnp.tanh(x @ p["w"] + p["b"])

x = jax.random.normal(jax.random.PRNGKey(2), (M, mb, dim))
out = pipeline_forward(stage_fn, {"w": W, "b": b}, x, mesh)

ref = x
for s in range(S):
    ref = jnp.tanh(ref @ W[s] + b[s])
err = float(jnp.max(jnp.abs(out - ref)))
print(f"pipeline output matches sequential: maxerr={err:.2e}")

rounds = num_pipeline_rounds(S, M)
bubble = pipeline_bubble_fraction(S, M)
print(f"stages={S} microbatches={M}: {rounds} rounds, "
      f"bubble={(rounds - M) / rounds:.3f} (paper model: {bubble:.3f})")
print("DONE")
"""


def main():
    env = {**os.environ, "PYTHONPATH": "src",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4"}
    r = subprocess.run([sys.executable, "-c", CODE], env=env, text=True,
                       capture_output=True, timeout=900)
    print(r.stdout)
    if "DONE" not in r.stdout:
        print(r.stderr[-2000:])
        sys.exit(1)


if __name__ == "__main__":
    main()
