"""Serving demo: train a tiny model on the copy task until it can copy, then
serve it two ways — the legacy batched loop (`serve.generate`, now with
one-shot batched prefill) and the continuous-batching engine (paged KV cache,
chunked prefill, mixed-length requests joining and leaving the batch). A
replay wave then shows prefix caching: repeated prompts alias their cached
KV blocks and skip most of prefill, with bit-identical outputs. The engine's
telemetry is read out along the way: per-request lifecycle timelines (TTFT,
queue wait), the compiled-step-variant count, a JSONL trace export replayed
back into the same timelines, and a Prometheus-format metric snapshot. An
oversubscription wave then serves the same requests through an optimistic
engine (prompt-only admission, on-demand decode-block growth) and forces a
mid-flight preemption: the victim's prefix is registered in the cache, the
request is evicted and later resumed, and its greedy output stays
bit-identical. A speculative wave then serves the same requests with
n-gram self-drafting — the copy task is the prompt-lookahead drafter's
best case, so each verify step advances several positions at once, still
bit-identical. A final hybrid-config wave smokes the per-layer state
providers end to end: a zamba2-style mamba2+shared-attention model served
through the same engine (recurrent slabs + paged KV behind one block
table), bit-identical to `serve.generate`.

    PYTHONPATH=src python examples/serve_demo.py
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import parallelism as par
from repro.data.pipeline import copy_task
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim import make_optimizer
from repro.serving import serve
from repro.serving.engine import (Engine, EngineConfig, OversubConfig,
                                  SpecConfig)
from repro.train import trainer


def main():
    cfg = ModelConfig(name="copy", family="dense", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256,
                      vocab_size=32, loss_chunk=32, attn_chunk=32, remat=False)
    plan = par.make_plan("dp", make_host_mesh())
    opt = make_optimizer("adam", lr=2e-3, grad_clip=1.0)
    state = trainer.init_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(trainer.make_train_step(cfg, opt, plan))

    seq = 32
    for i in range(250):
        batch = copy_task(32, seq, cfg.vocab_size, seed=i)
        state, m = step(state, batch)
        if i % 50 == 0:
            print(f"step {i:3d} loss {float(m['loss']):.4f}")

    # serve: prompt = [pattern, first half of its copy]; model must finish it
    test = copy_task(4, seq, cfg.vocab_size, seed=9999)
    half = seq // 2
    keep = half // 2
    prompt = test["tokens"][:, :half + keep]
    out = serve.generate(cfg, state["params"], jnp.asarray(prompt),
                         max_new=keep, temperature=0.0)
    expect = test["tokens"][:, half + keep:half + 2 * keep]
    acc = float(np.mean(np.asarray(out) == expect))
    print(f"legacy static batch: copy accuracy over {keep} tokens x4: {acc:.2f}")

    # engine: the same requests, but MIXED lengths — each request keeps a
    # different amount of the copy, so a static batch would have to pad
    eng = Engine(cfg, state["params"],
                 EngineConfig(block_size=8, num_blocks=64, max_blocks_per_seq=8,
                              max_slots=4, prefill_chunk=16))
    keeps = [keep, keep // 2, keep - 2, 3]
    rids, expects = [], []
    for b, kp in enumerate(keeps):
        p = test["tokens"][b, :half + kp]
        rids.append(eng.add_request(p, max_new=kp))
        expects.append(test["tokens"][b, half + kp:half + 2 * kp])
        eng.step()                       # requests arrive staggered
    outs = eng.drain()
    hits = sum(int(np.sum(outs[r] == e)) for r, e in zip(rids, expects))
    total = sum(len(e) for e in expects)
    print(f"engine (mixed lengths x4): copy accuracy {hits / total:.2f} "
          f"({eng.stats['decode_steps']} decode steps, "
          f"{eng.stats['prefill_chunks']} prefill chunks, "
          f"occupancy {eng.stats['occupancy_sum'] / max(eng.stats['decode_steps'], 1):.2f})")
    assert eng.block_pool.num_free == 64, "engine leaked KV blocks"

    # prefix caching: replay the same prompts — their full prompt blocks are
    # now in the prefix index, so prefill is (almost) entirely skipped and
    # the greedy outputs are bit-identical to the first wave
    chunks_before = eng.stats["prefill_chunks"]
    rids2 = [eng.add_request(test["tokens"][b, :half + kp], max_new=kp)
             for b, kp in enumerate(keeps)]
    outs2 = eng.drain()
    for r1, r2 in zip(rids, rids2):
        np.testing.assert_array_equal(outs[r1], outs2[r2])
    print(f"engine replay with prefix caching: "
          f"{eng.stats['prefix_hit_tokens']} prompt tokens served from cache, "
          f"{eng.stats['prefill_chunks'] - chunks_before} prefill chunks "
          f"(vs {chunks_before} cold), outputs bit-identical")
    assert eng.stats["prefix_hit_tokens"] > 0, "prefix cache never hit"
    assert eng.block_pool.num_free == 64, "engine leaked KV blocks"

    # telemetry readout: lifecycle timelines, recompile tracking, exporters
    from repro.serving import telemetry as TM
    tel = eng.telemetry
    for rid in rids:
        tl = tel.request_timeline(rid)
        print(f"  request {rid}: queue wait {tl['queue_wait'] * 1e3:.2f} ms, "
              f"TTFT {tl['ttft'] * 1e3:.2f} ms, "
              f"{len(tl['decode_tokens'])} decode tokens")
    print(f"compiled step variants: {tel.recompiles.total} "
          f"{tel.recompiles.variants()} — fixed across both waves, i.e. "
          f"zero serving-time recompilation")
    fd, trace_path = tempfile.mkstemp(suffix=".jsonl")
    os.close(fd)
    try:
        n_events = tel.export_jsonl(trace_path)
        replay = TM.replay_jsonl(trace_path)
        for rid in rids:
            assert replay[rid]["ttft"] == tel.request_timeline(rid)["ttft"]
        print(f"JSONL trace: {n_events} events exported and replayed into "
              f"{len(replay)} per-request timelines (TTFTs match live)")
    finally:
        os.unlink(trace_path)
    prom = tel.prometheus_text().splitlines()
    picks = [l for l in prom if l.startswith(("engine_tokens_emitted_total",
                                              "engine_prefix_hit_tokens",
                                              "pool_registrations_total",
                                              "engine_request_ttft"))]
    print("prometheus snapshot excerpt:")
    for line in picks[:6]:
        print(f"  {line}")

    # oversubscription wave: an optimistic engine admits with only its prompt
    # blocks reserved and appends decode blocks on demand; forcing a
    # preemption mid-flight exercises the full victim rollback — prefix
    # registered in the cache, request evicted, then resumed from the cached
    # prefix with bit-identical greedy output
    ov = Engine(cfg, state["params"],
                EngineConfig(block_size=8, num_blocks=24, max_blocks_per_seq=8,
                             max_slots=4, prefill_chunk=16,
                             oversub=OversubConfig()))
    ov_rids, ov_refs = [], []
    for b, kp in enumerate(keeps):
        p = test["tokens"][b, :half + kp]
        ov_rids.append(ov.add_request(p, max_new=kp, priority=b % 2))
        ref = serve.generate(cfg, state["params"], jnp.asarray(p)[None],
                             max_new=kp, temperature=0.0)
        ov_refs.append(np.asarray(ref)[0])
    for _ in range(3):
        ov.step()
    forced = next(r for r in ov_rids if ov.preempt_request(r))
    ov_outs = ov.drain()
    for r, ref in zip(ov_rids, ov_refs):
        np.testing.assert_array_equal(ov_outs[r], ref)
    tl = ov.telemetry.request_timeline(forced)
    print(f"engine oversubscription wave x{len(ov_rids)}: "
          f"{ov.stats['block_appends']} on-demand block appends, "
          f"{ov.stats['preemptions']} preemption(s), "
          f"{ov.stats['resumes']} resume(s), outputs bit-identical")
    print(f"  request {forced} was evicted mid-flight and resumed: "
          f"{tl['preempts']} preempt/resume cycle(s), "
          f"{tl['preempted_s'] * 1e3:.2f} ms out of the batch")
    assert ov.stats["preemptions"] >= 1 and ov.stats["resumes"] >= 1
    assert ov.block_pool.num_free == 24, "oversub engine leaked KV blocks"

    # speculative wave: the copy task is the n-gram drafter's best case —
    # the continuation has literally been seen before (it IS the pattern),
    # so the prompt-lookahead drafter proposes the true tokens and each
    # verify step advances several positions at once, bit-identically
    sp = Engine(cfg, state["params"],
                EngineConfig(block_size=8, num_blocks=64, max_blocks_per_seq=8,
                             max_slots=4, prefill_chunk=16,
                             spec=SpecConfig(k=6)))
    sp_rids = [sp.add_request(test["tokens"][b, :half + kp], max_new=kp)
               for b, kp in enumerate(keeps)]
    sp_outs = sp.drain()
    for r0, r in zip(rids, sp_rids):
        np.testing.assert_array_equal(outs[r0], sp_outs[r])
    reg = sp.telemetry.registry
    drafted = reg.get("engine_draft_tokens_total").value
    accepted = reg.get("engine_accepted_tokens_total").value
    vsteps = reg.get("engine_verify_steps_total").value
    emitted = sum(len(sp_outs[r]) for r in sp_rids)
    print(f"engine speculative wave (n-gram self-drafting, k=6) x"
          f"{len(sp_rids)}: {accepted}/{drafted} drafts accepted, "
          f"{(emitted - len(sp_rids)) / max(vsteps, 1):.2f} tokens/verify "
          f"step, outputs bit-identical")
    assert accepted > 0, "speculation never accepted a draft"

    # hybrid wave: mamba2 layers carry O(1) recurrent slabs, the shared
    # attention layer pages KV — the same engine serves both behind one
    # block table, matching serve.generate token for token
    hcfg = ModelConfig(name="copy-hybrid", family="hybrid",
                       hybrid_ssm_per_attn=1, num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                       vocab_size=32, loss_chunk=32, attn_chunk=32,
                       remat=False, dtype="float32", ssm_state_dim=8,
                       ssm_head_dim=32)
    hparams = T.init_params(hcfg, jax.random.PRNGKey(7))
    rng = np.random.default_rng(0)
    hprompts = [rng.integers(0, 32, size=int(n)).astype(np.int32)
                for n in (5, 11, 8, 3)]
    hnews = [6, 4, 9, 7]
    heng_cfg = EngineConfig(block_size=8, num_blocks=32, max_blocks_per_seq=8,
                            max_slots=4, prefill_chunk=8)
    houts = serve.engine_generate(hcfg, hparams, hprompts, hnews,
                                  engine_cfg=heng_cfg)
    for out, p, mn in zip(houts, hprompts, hnews):
        ref = serve.generate(hcfg, hparams, jnp.asarray(p)[None],
                             max_new=mn, temperature=0.0)
        np.testing.assert_array_equal(out, np.asarray(ref)[0])
    print(f"engine hybrid wave (mamba2 slabs + paged shared attention) x"
          f"{len(hprompts)}: outputs bit-identical to serve.generate")


if __name__ == "__main__":
    main()
