"""Batched serving demo: train a tiny model on the copy task until it can
copy, then serve batched requests token-by-token through the KV cache.

    PYTHONPATH=src python examples/serve_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import parallelism as par
from repro.data.pipeline import copy_task
from repro.launch.mesh import make_host_mesh
from repro.optim import make_optimizer
from repro.serving import serve
from repro.train import trainer


def main():
    cfg = ModelConfig(name="copy", family="dense", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256,
                      vocab_size=32, loss_chunk=32, attn_chunk=32, remat=False)
    plan = par.make_plan("dp", make_host_mesh())
    opt = make_optimizer("adam", lr=2e-3, grad_clip=1.0)
    state = trainer.init_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(trainer.make_train_step(cfg, opt, plan))

    seq = 32
    for i in range(250):
        batch = copy_task(32, seq, cfg.vocab_size, seed=i)
        state, m = step(state, batch)
        if i % 50 == 0:
            print(f"step {i:3d} loss {float(m['loss']):.4f}")

    # serve: prompt = [pattern, first half of its copy]; model must finish it
    test = copy_task(4, seq, cfg.vocab_size, seed=9999)
    half = seq // 2
    keep = half // 2
    prompt = test["tokens"][:, :half + keep]
    out = serve.generate(cfg, state["params"], jnp.asarray(prompt),
                         max_new=keep, temperature=0.0)
    expect = test["tokens"][:, half + keep:half + 2 * keep]
    acc = float(np.mean(np.asarray(out) == expect))
    print(f"copy-task decode accuracy over {keep} tokens x4 requests: {acc:.2f}")


if __name__ == "__main__":
    main()
