"""Quickstart: train a small decoder on synthetic data with the public API.

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced yi-9b-family config, trains 100 steps of minibatch SGD with
Adam (survey Algorithm 2 + Table 3), prints the loss curve, saves and
restores a checkpoint, and greedily decodes a few tokens.
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core import parallelism as par
from repro.data.pipeline import SyntheticLM, shard_batch
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim import make_optimizer
from repro.serving import serve
from repro.train import checkpoint as ckpt
from repro.train import trainer


def main():
    cfg = reduced(get_config("yi-9b"))
    print(f"arch={cfg.name} params={cfg.param_count():,}")

    mesh = make_host_mesh()
    plan = par.make_plan("dp", mesh)
    opt = make_optimizer("adam", lr=3e-3, grad_clip=1.0)
    state = trainer.init_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(trainer.make_train_step(cfg, opt, plan))

    data = SyntheticLM(cfg.vocab_size, seq_len=64, noise=0.05)
    for i, batch in enumerate(data.batches(batch_size=16, steps=100)):
        state, metrics = step(state, shard_batch(batch, plan))
        if i % 10 == 0:
            print(f"step {i:3d}  loss {float(metrics['loss']):.4f}")

    path = ckpt.save("/tmp/quickstart_ckpt.npz", state, step=100)
    restored, at = ckpt.restore(path, jax.eval_shape(lambda: state))
    print(f"checkpoint roundtrip ok (step {at})")

    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    out = serve.generate(cfg, restored["params"], prompt, max_new=8,
                         temperature=0.0)
    print("greedy continuation:", out[0].tolist())


if __name__ == "__main__":
    main()
