"""Survey §6.3 end-to-end: train the same model under different gradient
compressors (with error feedback) in the explicit-collective "paper mode"
and compare loss curves + wire bytes.

    PYTHONPATH=src python examples/compression_comparison.py
    (spawns a 4-device subprocess internally if run on 1 device)

Reproduces the survey's central compression claim: with local gradient
accumulation (error feedback), even 1-bit / top-1% gradients track the
uncompressed loss curve closely while moving 30–2000x fewer bytes.
"""
import os
import subprocess
import sys

CODE = """
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig
from repro.core.compression import make_compressor
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.optim import make_optimizer
from repro.train import trainer

cfg = ModelConfig(name="c", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                  vocab_size=64, loss_chunk=32, attn_chunk=32, remat=False)
mesh = make_host_mesh((len(jax.devices()),), ("data",))
data = SyntheticLM(cfg.vocab_size, 64, noise=0.05)
batches = list(data.batches(16, 60))
n_params = cfg.param_count()

for name in ("none", "int8", "onebit", "topk"):
    comp = None if name == "none" else make_compressor(name, frac=0.01)
    opt = make_optimizer("adam", lr=3e-3)
    state = trainer.init_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(trainer.make_paper_train_step(
        cfg, opt, mesh, algorithm="ring", compression=comp))
    residual = (trainer.zero_residual(state["params"]) if comp
                else {"_": jnp.zeros((1,), jnp.float32)})
    losses = []
    for b in batches:
        state, m, residual = step(state, b, residual)
        losses.append(float(m["loss"]))
    ratio = 1.0 if comp is None else comp.ratio()
    wire_mb = n_params * 4 / ratio / 1e6
    print(f"{name:8s} first5={sum(losses[:5])/5:.3f} "
          f"last5={sum(losses[-5:])/5:.3f} wire={wire_mb:.2f}MB/step "
          f"({ratio:.0f}x compression)")
print("DONE")
"""


def main():
    env = {**os.environ, "PYTHONPATH": "src",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4"}
    r = subprocess.run([sys.executable, "-c", CODE], env=env, text=True,
                       capture_output=True, timeout=1800)
    print(r.stdout)
    if "DONE" not in r.stdout:
        print(r.stderr[-2000:])
        sys.exit(1)


if __name__ == "__main__":
    main()
