"""Per-architecture smoke tests (deliverable (f)): REDUCED variant of each
family — one forward/train step on CPU, asserting shapes + no NaNs — plus a
serve step for decode-capable archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config, list_configs, reduced
from repro.models import transformer as T
from repro.optim import make_optimizer
from repro.train import trainer

ARCHS = list_configs()
B, S = 2, 64


def make_batch(cfg, key):
    batch = {}
    if cfg.frontend != "none":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model))
        if cfg.rope_mode == "mrope":
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(S)[None, None], (3, B, S))
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_reduced_is_actually_reduced(self, arch):
        cfg = reduced(get_config(arch))
        assert cfg.num_layers <= 2
        assert cfg.d_model <= 512
        assert cfg.num_experts <= 4

    def test_forward_shapes_no_nan(self, arch):
        cfg = reduced(get_config(arch))
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        hidden, aux = T.forward(cfg, params, batch)
        assert hidden.shape == (B, S, cfg.d_model)
        assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))
        assert bool(jnp.isfinite(aux))

    def test_train_step_loss_finite(self, arch):
        cfg = reduced(get_config(arch))
        opt = make_optimizer("adam", lr=1e-3)
        state = trainer.init_state(cfg, opt, jax.random.PRNGKey(0))
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        from repro.launch.mesh import make_host_mesh
        from repro.core import parallelism as par
        plan = par.make_plan("dp", make_host_mesh())
        step = jax.jit(trainer.make_train_step(cfg, opt, plan))
        new_state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss)
        assert 0.0 < loss < 3.0 * np.log(cfg.vocab_size)
        # params actually changed
        before = jax.tree_util.tree_leaves(state["params"])[1]
        after = jax.tree_util.tree_leaves(new_state["params"])[1]
        assert not bool(jnp.all(before == after))

    def test_serve_step(self, arch):
        cfg = reduced(get_config(arch))
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        cache = T.init_decode_state(cfg, B, 32)
        inputs = ({"token": jnp.ones((B,), jnp.int32)}
                  if cfg.frontend == "none"
                  else {"embed": jax.random.normal(jax.random.PRNGKey(2),
                                                   (B, cfg.d_model))})
        lg, cache2 = T.decode_step(cfg, params, cache, inputs, jnp.int32(3))
        assert lg.shape == (B, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))
        changed = any(
            not bool(jnp.all(a == b))
            for a, b in zip(jax.tree_util.tree_leaves(cache),
                            jax.tree_util.tree_leaves(cache2)))
        assert changed


class TestFullConfigsConsistent:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_config_metadata(self, arch):
        cfg = get_config(arch)
        assert cfg.source
        n = cfg.param_count()
        # sanity: parameter count within 3x of the name-plate size
        plate = {"gemma3-12b": 12e9, "phi4-mini-3.8b": 3.8e9, "qwen2-vl-2b": 2e9,
                 "mixtral-8x7b": 47e9, "stablelm-3b": 3e9, "rwkv6-7b": 7e9,
                 "yi-9b": 9e9, "qwen3-moe-30b-a3b": 30e9, "zamba2-2.7b": 2.7e9,
                 "musicgen-medium": 1.5e9}[arch]
        assert plate / 3 < n < plate * 3, f"{arch}: {n:.2e} vs {plate:.2e}"

    def test_long_context_applicability(self):
        from repro.launch.specs import shape_applicable
        runs = {a for a in ARCHS
                if shape_applicable(get_config(a), SHAPES["long_500k"])[0]}
        assert runs == {"gemma3-12b", "mixtral-8x7b", "rwkv6-7b", "zamba2-2.7b"}
