"""MoE dispatch tests: sort/capacity dispatch vs dense-masked reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import moe as M


def moe_cfg(E=4, k=2, cap=8.0):
    return ModelConfig(name="t", family="moe", d_model=32, num_heads=2,
                       num_kv_heads=2, d_ff=64, vocab_size=17,
                       num_experts=E, experts_per_token=k, capacity_factor=cap)


def dense_moe_reference(params, x, cfg):
    """Every expert computes every token; combine with top-k router probs."""
    B, Sq, D = x.shape
    xt = x.reshape(-1, D)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    g = jnp.einsum("td,edf->etf", xt, params["w_gate"])
    h = jnp.einsum("td,edf->etf", xt, params["w_in"])
    act = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    y_all = jnp.einsum("etf,efd->etd", act, params["w_out"])    # (E, T, D)
    combine = jnp.zeros((xt.shape[0], cfg.num_experts), jnp.float32)
    combine = combine.at[jnp.arange(xt.shape[0])[:, None], top_e].set(top_p)
    out = jnp.einsum("te,etd->td", combine.astype(x.dtype), y_all)
    return out.reshape(B, Sq, D)


class TestDispatch:
    def test_matches_dense_reference_with_ample_capacity(self):
        cfg = moe_cfg(cap=8.0)      # capacity >> tokens: no drops
        p = M.init_moe(jax.random.PRNGKey(0), cfg)
        x = (jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
             ).astype(jnp.bfloat16)
        out = M.moe_apply(p, x, cfg)
        ref = dense_moe_reference(p, x, cfg)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=0.15, rtol=0.1)

    def test_capacity_drops_dont_crash_or_nan(self):
        cfg = moe_cfg(cap=0.25)     # aggressive drops
        p = M.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32)).astype(jnp.bfloat16)
        out = M.moe_apply(p, x, cfg)
        assert out.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))

    def test_gradients_flow(self):
        cfg = moe_cfg()
        p = M.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32)).astype(jnp.bfloat16)

        def loss(p_):
            return jnp.sum(M.moe_apply(p_, x, cfg).astype(jnp.float32) ** 2)

        g = jax.grad(loss)(p)
        gn = sum(float(jnp.linalg.norm(v.astype(jnp.float32)))
                 for v in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0

    def test_load_balance_loss_bounds(self):
        """aux ∈ [k, E·k-ish]; uniform routing → ≈ k (paper-standard aux)."""
        cfg = moe_cfg(E=8, k=2)
        p = M.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32)).astype(jnp.bfloat16)
        aux = float(M.load_balance_loss(p, x, cfg))
        assert 1.0 < aux < 17.0
