"""Packed multi-request prefill with AOT-warmed (chunk x segments) buckets,
plus the scheduler/pool/host-sync bugfix regressions that ride along:

  * warmup compiles EXACTLY the declared bucket grid and steady-state
    serving never adds a prefill trace key;
  * bucket-edge cases — prompt shorter than the smallest bucket, a chunk
    crossing a bucket boundary, a packed call mixing a fresh request with a
    prefix-cache CoW tail — all bit-identical to `serve.generate`;
  * packing on vs off is bit-identical for every family;
  * `Scheduler.occupancy()` counts only DECODING slots (matches
    `engine_occupancy_sum`);
  * `drop_cache()` returns content-forgotten blocks to reuse-first order
    and `num_cached_free` is an O(1) maintained counter;
  * stop_token scanning materializes each step vector at most once and
    `drain(max_steps=N)` runs at most N steps.

All CPU. Select with `pytest -m aot_prefill` (subset of `-m serving`).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serving import serve
from repro.serving.engine import BlockPool, Engine, EngineConfig
from repro.serving.engine.paged_cache import prefix_hashes
from repro.serving.engine.scheduler import (DECODING, PREFILLING,
                                            chunk_buckets_for,
                                            segment_buckets_for)

pytestmark = [pytest.mark.serving, pytest.mark.aot_prefill]

_COMMON = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
               head_dim=16, d_ff=128, vocab_size=50, loss_chunk=16,
               attn_chunk=16, remat=False, dtype="float32")

FAMILIES = ("full", "sliding", "ssm", "hybrid")


def family_cfg(family: str) -> ModelConfig:
    if family == "full":
        return ModelConfig(name="pp-full", family="dense", **_COMMON)
    if family == "sliding":
        return ModelConfig(name="pp-sliding", family="dense",
                           attention_type="sliding", window_size=8, **_COMMON)
    if family == "ssm":
        return ModelConfig(name="pp-ssm", family="ssm", ssm_type="rwkv6",
                           ssm_head_dim=32, **_COMMON)
    if family == "hybrid":
        return ModelConfig(name="pp-hybrid", family="hybrid",
                           hybrid_ssm_per_attn=1, ssm_state_dim=8,
                           ssm_head_dim=32, **_COMMON)
    raise ValueError(family)


@pytest.fixture(scope="module")
def fam_params():
    cache = {}

    def get(family):
        if family not in cache:
            cfg = family_cfg(family)
            cache[family] = (cfg, T.init_params(cfg, jax.random.PRNGKey(0)))
        return cache[family]

    return get


def _engine(cfg, params, **kw):
    base = dict(block_size=4, num_blocks=64, max_blocks_per_seq=16,
                max_slots=4, prefill_chunk=8)
    base.update(kw)
    return Engine(cfg, params, EngineConfig(**base))


def _ref_out(cfg, params, prompt, max_new):
    return np.asarray(serve.generate(
        cfg, params, jnp.asarray(prompt)[None], max_new=max_new,
        temperature=0.0))[0]


# ----------------------------------------------------- bucket declarations
class TestBucketDeclaration:
    def test_chunk_bucket_normalization(self):
        assert chunk_buckets_for(32) == (32,)
        assert chunk_buckets_for(32, (8, 16)) == (8, 16, 32)
        assert chunk_buckets_for(32, (16, 8, 16)) == (8, 16, 32)
        assert chunk_buckets_for(32, (32,)) == (32,)

    def test_chunk_bucket_bounds_rejected(self):
        with pytest.raises(ValueError, match="outside"):
            chunk_buckets_for(32, (64,))
        with pytest.raises(ValueError, match="outside"):
            chunk_buckets_for(32, (0,))

    def test_segment_buckets(self):
        assert segment_buckets_for(1) == (1,)
        assert segment_buckets_for(2) == (1, 2)
        assert segment_buckets_for(3) == (1, 2, 3)
        assert segment_buckets_for(4) == (1, 2, 4)
        assert segment_buckets_for(6) == (1, 2, 4, 6)
        assert segment_buckets_for(5, packed=False) == (1,)

    def test_bucket_knobs_normalized_out_of_compile_key(self):
        from repro.serving.engine.engine import _step_fn_key
        assert _step_fn_key(EngineConfig(prefill_buckets=(8, 16),
                                         packed_prefill=False)) \
            == _step_fn_key(EngineConfig())


# ------------------------------------------------------------- AOT warmup
class TestAOTWarmup:
    def test_warmup_compiles_declared_buckets_exactly(self, fam_params):
        """`compiled_step_variants["prefill"]` equals the declared bucket
        count right after construction, and a whole served workload adds
        ZERO new prefill trace keys."""
        cfg, params = fam_params("full")
        eng = _engine(cfg, params, prefill_buckets=(2, 8),
                      prefills_per_step=3)
        assert eng.chunk_buckets == (2, 8)
        assert eng.segment_buckets == (1, 2, 3)
        declared = len(eng.prefill_grid)
        assert declared == 6
        assert eng.telemetry.recompiles.variants()["prefill"] == declared

        rng = np.random.default_rng(0)
        for L, mn in ((1, 4), (9, 3), (16, 2), (5, 5), (5, 5)):
            eng.add_request(rng.integers(0, 50, size=L).astype(np.int32), mn)
        eng.drain()
        assert eng.telemetry.recompiles.variants()["prefill"] == declared
        assert sum(eng.bucket_dispatches().values()) > 0

    def test_unpacked_mode_also_stays_warm(self, fam_params):
        cfg, params = fam_params("full")
        eng = _engine(cfg, params, packed_prefill=False, prefills_per_step=2)
        declared = len(eng.prefill_grid)
        assert eng.segment_buckets == (1,)
        rng = np.random.default_rng(1)
        for L in (3, 11, 6):
            eng.add_request(rng.integers(0, 50, size=L).astype(np.int32), 3)
        eng.drain()
        assert eng.telemetry.recompiles.variants()["prefill"] == declared


# ------------------------------------------------------ packed edge cases
class TestPackedPrefillEdges:
    def test_prompt_shorter_than_smallest_bucket(self, fam_params):
        """A 2-token prompt with smallest bucket 4 pads up to C=4 and stays
        bit-identical to the oracle."""
        cfg, params = fam_params("full")
        eng = _engine(cfg, params, prefill_buckets=(4,))
        rng = np.random.default_rng(2)
        p = rng.integers(0, 50, size=2).astype(np.int32)
        rid = eng.add_request(p, 6)
        outs = eng.drain()
        np.testing.assert_array_equal(outs[rid], _ref_out(cfg, params, p, 6))
        assert eng.bucket_dispatches()[(4, 1)] == 1

    def test_chunk_crossing_bucket_boundary(self, fam_params):
        """An 11-token prompt at prefill_chunk 8 with buckets (4, 8) splits
        into one C=8 chunk and one C=4 chunk (the 3-token tail crosses down
        a bucket), still bit-identical."""
        cfg, params = fam_params("full")
        eng = _engine(cfg, params, prefill_buckets=(4, 8))
        rng = np.random.default_rng(3)
        p = rng.integers(0, 50, size=11).astype(np.int32)
        rid = eng.add_request(p, 5)
        outs = eng.drain()
        np.testing.assert_array_equal(outs[rid], _ref_out(cfg, params, p, 5))
        d = eng.bucket_dispatches()
        assert d[(8, 1)] == 1 and d[(4, 1)] == 1

    def test_packed_mixes_fresh_and_cow_tail(self, fam_params):
        """One packed call carries a fully-cached request's copy-on-write
        final-token segment (valid=1) next to a fresh request's full chunk
        — both bit-identical, one dispatch at the G=2 bucket."""
        cfg, params = fam_params("full")
        eng = _engine(cfg, params, prefills_per_step=2)
        rng = np.random.default_rng(4)
        pa = rng.integers(0, 50, size=8).astype(np.int32)   # 2 full blocks
        pb = rng.integers(0, 50, size=7).astype(np.int32)
        r0 = eng.add_request(pa, 3)
        eng.drain()                                  # prime the prefix cache
        before = eng.bucket_dispatches()
        ra = eng.add_request(pa, 4)                  # fully cached -> CoW
        rb = eng.add_request(pb, 4)                  # fresh
        eng.step()                                   # both packed together
        assert eng.stats["cow_copies"] == 1
        outs = eng.drain()
        np.testing.assert_array_equal(outs[r0], _ref_out(cfg, params, pa, 3))
        np.testing.assert_array_equal(outs[ra], _ref_out(cfg, params, pa, 4))
        np.testing.assert_array_equal(outs[rb], _ref_out(cfg, params, pb, 4))
        assert eng.bucket_dispatches()[(8, 2)] == before.get((8, 2), 0) + 1

    @pytest.mark.parametrize("family", FAMILIES)
    def test_packed_equals_unpacked_all_families(self, family, fam_params):
        """Greedy outputs are bit-identical to `serve.generate` with packing
        ON (multi-segment calls) and OFF (B=1 calls) for every family."""
        cfg, params = fam_params(family)
        rng = np.random.default_rng(5)
        prompts = [rng.integers(0, 50, size=L).astype(np.int32)
                   for L in (3, 11, 6)]
        news = [14, 4, 9]                       # 14 > ring capacity 3*4 = 12
        for packed in (True, False):
            eng = _engine(cfg, params, prefills_per_step=3,
                          packed_prefill=packed)
            rids = [eng.add_request(p, mn) for p, mn in zip(prompts, news)]
            outs = eng.drain()
            for rid, p, mn in zip(rids, prompts, news):
                np.testing.assert_array_equal(
                    outs[rid], _ref_out(cfg, params, p, mn))


# ------------------------------------------------- satellite bug regressions
class TestOccupancyDecodeOnly:
    def test_scheduler_matches_engine_metric_on_mixed_step(self, fam_params):
        """On a step mixing one DECODING and one PREFILLING request, both
        occupancy reports count only the decode slot."""
        cfg, params = fam_params("full")
        eng = _engine(cfg, params, prefill_chunk=4)
        rng = np.random.default_rng(6)
        eng.add_request(rng.integers(0, 50, size=3).astype(np.int32), 8)
        eng.step()                              # request A now DECODING
        rb = eng.add_request(rng.integers(0, 50, size=10).astype(np.int32), 4)
        occ0 = eng.stats["occupancy_sum"]
        eng.step()                              # B admitted, mid-prefill
        assert eng.requests[rb].state == PREFILLING
        step_occ = eng.stats["occupancy_sum"] - occ0
        assert step_occ == 1 / eng.ecfg.max_slots
        assert eng.scheduler.occupancy() == step_occ


class TestDropCacheAndCounter:
    def test_drop_cache_returns_blocks_reuse_first(self):
        pool = BlockPool(8, 4)
        keys = prefix_hashes(np.arange(8, dtype=np.int32), 4)
        got = pool.alloc("a", 2)                # blocks [0, 1]
        for b, k in zip(got, keys):
            pool.register("a", b, k)
        pool.free_seq("a")
        assert pool.num_cached_free == 2
        assert pool.drop_cache() == 2
        assert pool.num_cached_free == 0
        pool.check()
        # content-forgotten blocks are plain garbage now: they must be
        # handed out BEFORE never-used blocks (reuse-first), not stranded
        # at the evict-last end
        assert set(pool.alloc("b", 2)) == set(got)
        pool.check()

    def test_cached_free_counter_tracks_scan(self):
        pool = BlockPool(6, 4)
        keys = prefix_hashes(np.arange(12, dtype=np.int32), 4)
        blocks = pool.alloc("a", 3)
        for b, k in zip(blocks, keys):
            pool.register("a", b, k)
        pool.free_seq("a")
        pool.check()
        assert pool.num_cached_free == 3
        pool.share("b", [blocks[0]])            # revive off the free list
        pool.check()
        assert pool.num_cached_free == 2
        pool.alloc("c", 4)                      # 3 plain + 1 LRU eviction
        pool.check()
        assert pool.num_cached_free == 1
        assert pool.stats["evictions"] == 1
        pool.free_seq("b")                      # still registered -> cached
        pool.check()
        assert pool.num_cached_free == 2
        pool.drop_cache()
        pool.check()
        assert pool.num_cached_free == 0


class TestHostSyncAndDrain:
    def test_stop_token_syncs_once_per_step_vector(self, fam_params):
        """Three stop_token requests prefilled in ONE packed call and decoded
        in lockstep materialize each step vector exactly once: 1 prefill
        vector + 1 per decode step, not one transfer per request."""
        cfg, params = fam_params("full")
        eng = _engine(cfg, params, prefills_per_step=4, prefix_caching=False)
        rng = np.random.default_rng(7)
        prompts = [rng.integers(0, 50, size=5).astype(np.int32)
                   for _ in range(3)]
        mn = 6
        rids = [eng.add_request(p, mn, stop_token=49_999) for p in prompts]
        outs = eng.drain()
        syncs = eng.telemetry.registry.get(
            "engine_step_vector_syncs_total").value
        assert syncs == 1 + eng.stats["decode_steps"]
        assert eng.stats["decode_steps"] == mn - 1
        for rid, p in zip(rids, prompts):
            np.testing.assert_array_equal(
                outs[rid], _ref_out(cfg, params, p, mn))

    def test_stop_token_still_stops(self, fam_params):
        """The memoized path still honors the stop token."""
        cfg, params = fam_params("full")
        rng = np.random.default_rng(8)
        p = rng.integers(0, 50, size=4).astype(np.int32)
        ref = _ref_out(cfg, params, p, 12)
        stop = int(ref[3])                      # force a mid-stream stop
        eng = _engine(cfg, params)
        rid = eng.add_request(p, 12, stop_token=stop)
        out = eng.drain()[rid]
        assert out.shape[0] <= 12
        assert out[-1] == stop
        np.testing.assert_array_equal(out, ref[:out.shape[0]])

    def test_drain_runs_at_most_max_steps(self, fam_params):
        cfg, params = fam_params("full")
        eng = _engine(cfg, params)
        rng = np.random.default_rng(9)
        eng.add_request(rng.integers(0, 50, size=4).astype(np.int32), 50)
        calls = []
        orig = eng.step
        eng.step = lambda: (calls.append(1), orig())[1]
        with pytest.raises(RuntimeError, match="did not converge"):
            eng.drain(max_steps=3)
        assert len(calls) == 3
