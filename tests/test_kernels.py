"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles, executed
with interpret=True on CPU (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.matmul import matmul, matmul_ref
from repro.kernels.quantize import dequantize_blocks, quantize_blocks
from repro.kernels.quantize.ref import dequantize_blocks_ref, quantize_blocks_ref


class TestMatmulSweep:
    @pytest.mark.parametrize("M,K,N", [
        (128, 128, 128), (256, 512, 128), (128, 1024, 256), (384, 256, 640),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose_vs_ref(self, M, K, N, dtype):
        ka, kb = jax.random.split(jax.random.PRNGKey(M + N))
        a = jax.random.normal(ka, (M, K), jnp.float32).astype(dtype)
        b = jax.random.normal(kb, (K, N), jnp.float32).astype(dtype)
        out = matmul(a, b, block_m=128, block_n=128, block_k=128)
        ref = matmul_ref(a, b)
        tol = 2e-6 * K if dtype == jnp.float32 else 0.15
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=0.05)

    def test_block_shape_invariance(self):
        a = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
        outs = [np.asarray(matmul(a, b, block_m=bm, block_n=bn, block_k=bk))
                for bm, bn, bk in [(64, 64, 64), (128, 256, 128), (256, 256, 512)]]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-4)


class TestFlashAttentionSweep:
    @pytest.mark.parametrize("S,H,Hkv,hd", [
        (128, 4, 4, 64), (256, 4, 2, 64), (256, 8, 1, 128), (512, 2, 2, 64),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_allclose(self, S, H, Hkv, hd, dtype):
        B = 2
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(S + hd), 3)
        q = jax.random.normal(k1, (B, S, H, hd), jnp.float32).astype(dtype)
        k = jax.random.normal(k2, (B, S, Hkv, hd), jnp.float32).astype(dtype)
        v = jax.random.normal(k3, (B, S, Hkv, hd), jnp.float32).astype(dtype)
        out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
        rep = H // Hkv
        kk, vv = jnp.repeat(k, rep, 2), jnp.repeat(v, rep, 2)
        tb = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        ref = attention_ref(tb(q), tb(kk), tb(vv), causal=True) \
            .reshape(B, H, S, hd).transpose(0, 2, 1, 3)
        tol = 1e-5 if dtype == jnp.float32 else 0.08
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=tol)

    @pytest.mark.parametrize("window", [32, 128])
    def test_sliding_window(self, window):
        B, S, H, hd = 1, 256, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(window), 3)
        q, k, v = (jax.random.normal(kk, (B, S, H, hd)) for kk in ks)
        out = flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
        tb = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        ref = attention_ref(tb(q), tb(k), tb(v), causal=True, window=window) \
            .reshape(B, H, S, hd).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

    def test_matches_model_chunked_attention_semantics(self):
        """The kernel and the model's XLA chunked path agree."""
        from repro.models import attention as A
        B, S, H, hd = 1, 128, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(9), 3)
        q, k, v = (jax.random.normal(kk, (B, S, H, hd), jnp.float32) for kk in ks)
        out_kernel = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
        out_model = A._chunked_attention(q, k, v, n_rep=1, scale=hd ** -0.5,
                                         chunk=32, window=None)
        np.testing.assert_allclose(np.asarray(out_kernel),
                                   np.asarray(out_model), atol=2e-5)


class TestQuantizeSweep:
    @pytest.mark.parametrize("n", [256, 1000, 4096, 65_537])
    @pytest.mark.parametrize("bits", [8, 4])
    def test_kernel_equals_ref(self, n, bits):
        key = jax.random.PRNGKey(n + bits)
        flat = jax.random.normal(key, (n,)) * 0.02
        q, s = quantize_blocks(flat, key, bits=bits)
        pad = (-n) % 256
        x = jnp.pad(flat, (0, pad)).reshape(-1, 256)
        noise = jax.random.uniform(key, x.shape)
        qr, sr = quantize_blocks_ref(x, noise, bits=bits)
        assert bool(jnp.all(q == qr))
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)

    def test_roundtrip_bounded_error(self):
        key = jax.random.PRNGKey(5)
        flat = jax.random.normal(key, (8192,))
        q, s = quantize_blocks(flat, key, bits=8)
        deq = dequantize_blocks(q, s, n=8192)
        # error per element ≤ scale = max|block|/127
        err = float(jnp.max(jnp.abs(deq - flat)))
        assert err <= float(jnp.max(s)) + 1e-6

    def test_zero_block_safe(self):
        flat = jnp.zeros((512,))
        q, s = quantize_blocks(flat, jax.random.PRNGKey(0))
        deq = dequantize_blocks(q, s, n=512)
        assert bool(jnp.all(deq == 0))

    def test_nearest_deterministic_without_key(self):
        """mode='nearest' needs no PRNG key (the serving KV path runs inside
        jitted engine steps with no key plumbing) and is a pure function of
        the input."""
        flat = jax.random.normal(jax.random.PRNGKey(7), (4096,)) * 0.05
        q1, s1 = quantize_blocks(flat, mode="nearest")
        q2, s2 = quantize_blocks(flat, mode="nearest")
        assert bool(jnp.all(q1 == q2)) and bool(jnp.all(s1 == s2))
        # kernel matches the nearest-mode reference exactly
        x = flat.reshape(-1, 256)
        qr, sr = quantize_blocks_ref(x, bits=8, mode="nearest")
        assert bool(jnp.all(q1 == qr))
        np.testing.assert_allclose(np.asarray(s1), np.asarray(sr), rtol=1e-6)

    def test_nearest_tighter_roundtrip_than_stochastic(self):
        """Nearest rounding halves the worst-case round-trip error: per
        element <= scale/2, where the stochastic path only guarantees
        <= scale (its expectation, not its max, is exact)."""
        key = jax.random.PRNGKey(11)
        flat = jax.random.normal(key, (8192,))
        qn, sn = quantize_blocks(flat, mode="nearest")
        err_n = jnp.abs(dequantize_blocks(qn, sn, n=8192) - flat)
        per_block = jnp.repeat(sn, 256)[:8192]
        assert bool(jnp.all(err_n <= per_block / 2 + 1e-6))
        qs, ss = quantize_blocks(flat, key)
        err_s = jnp.abs(dequantize_blocks(qs, ss, n=8192) - flat)
        assert bool(jnp.all(err_s <= jnp.repeat(ss, 256)[:8192] + 1e-6))

    def test_stochastic_requires_key(self):
        with pytest.raises(ValueError):
            quantize_blocks(jnp.zeros((256,)))

    def test_kv_quant_roundtrip(self):
        """The per-vector KV quantizer: nearest, per-(token, head) scales
        over head_dim, exact zeros, error <= scale/2."""
        from repro.kernels.quantize import dequantize_kv, quantize_kv
        x = jax.random.normal(jax.random.PRNGKey(3), (4, 16, 2, 64))
        q, s = quantize_kv(x)
        assert q.dtype == jnp.int8 and s.shape == x.shape[:-1]
        err = jnp.abs(dequantize_kv(q, s) - x)
        assert bool(jnp.all(err <= s[..., None] / 2 + 1e-6))
        qz, sz = quantize_kv(jnp.zeros((2, 8, 2, 64)))
        assert bool(jnp.all(qz == 0)) and bool(jnp.all(sz == 1.0))
        assert bool(jnp.all(dequantize_kv(qz, sz) == 0))
