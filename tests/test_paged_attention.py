"""Paged-decode attention: Pallas kernel (interpret mode) vs pure-jnp oracle,
and the oracle vs a contiguous masked-attention reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_ref,
                                           paged_attention_verify,
                                           paged_attention_verify_ref)
from repro.kernels.quantize import dequantize_kv, quantize_kv
from repro.models import state_providers as SP

pytestmark = pytest.mark.serving

NEG_INF = -1e30


def _random_case(key, B, H, Hkv, hd, N, bs, P, dtype, lens):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    q = jax.random.normal(k1, (B, H, hd), jnp.float32).astype(dtype)
    kp = jax.random.normal(k2, (N, bs, Hkv, hd), jnp.float32).astype(dtype)
    vp = jax.random.normal(k3, (N, bs, Hkv, hd), jnp.float32).astype(dtype)
    # distinct random blocks per sequence (no aliasing between sequences)
    perm = jax.random.permutation(k4, N)[:B * P]
    tables = perm.reshape(B, P).astype(jnp.int32)
    return q, kp, vp, tables, jnp.asarray(lens, jnp.int32)


class TestPagedAttentionSweep:
    @pytest.mark.parametrize("H,Hkv,hd", [(4, 4, 32), (4, 2, 64), (8, 1, 32)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_kernel_vs_ref(self, H, Hkv, hd, dtype):
        B, N, bs, P = 3, 24, 8, 4
        # lengths cross page boundaries, fill exactly, and include a mid-page
        lens = [1, bs * P, bs + 3]
        q, kp, vp, tables, lens = _random_case(
            jax.random.PRNGKey(H * 100 + hd), B, H, Hkv, hd, N, bs, P,
            dtype, lens)
        out = paged_attention(q, kp, vp, tables, lens)
        ref = paged_attention_ref(q, kp, vp, tables, lens)
        tol = 2e-5 if dtype == jnp.float32 else 0.08
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=tol)

    def test_inactive_slot_outputs_zero(self):
        B, H, Hkv, hd, N, bs, P = 2, 4, 2, 32, 8, 4, 2
        q, kp, vp, tables, lens = _random_case(
            jax.random.PRNGKey(0), B, H, Hkv, hd, N, bs, P, jnp.float32,
            [5, 0])
        for out in (paged_attention(q, kp, vp, tables, lens),
                    paged_attention_ref(q, kp, vp, tables, lens)):
            assert bool(jnp.all(out[1] == 0))
            assert bool(jnp.all(jnp.isfinite(out)))

    def test_garbage_beyond_seq_len_is_masked(self):
        """Blocks past seq_len may contain stale data from freed sequences."""
        B, H, Hkv, hd, N, bs, P = 1, 2, 2, 32, 6, 4, 3
        key = jax.random.PRNGKey(7)
        q, kp, vp, tables, lens = _random_case(
            key, B, H, Hkv, hd, N, bs, P, jnp.float32, [6])
        out1 = paged_attention(q, kp, vp, tables, lens)
        # poison everything at/after position 6 in this sequence's pages
        kp2 = kp.at[tables[0, 1], 2:].set(1e4).at[tables[0, 2]].set(1e4)
        vp2 = vp.at[tables[0, 1], 2:].set(1e4).at[tables[0, 2]].set(1e4)
        out2 = paged_attention(q, kp2, vp2, tables, lens)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)

    def test_ref_matches_contiguous_attention(self):
        """Scatter a contiguous sequence into pages -> paged ref equals plain
        masked decode attention over the contiguous K/V."""
        B, H, Hkv, hd, bs, P = 2, 4, 2, 16, 4, 4
        N = B * P
        L = [11, 7]
        key = jax.random.PRNGKey(3)
        k1, k2, k3 = jax.random.split(key, 3)
        q = jax.random.normal(k1, (B, H, hd))
        k_ctg = jax.random.normal(k2, (B, P * bs, Hkv, hd))
        v_ctg = jax.random.normal(k3, (B, P * bs, Hkv, hd))
        tables = jnp.arange(N, dtype=jnp.int32).reshape(B, P)
        kp = k_ctg.reshape(B * P, bs, Hkv, hd)
        vp = v_ctg.reshape(B * P, bs, Hkv, hd)
        lens = jnp.asarray(L, jnp.int32)
        out = paged_attention_ref(q, kp, vp, tables, lens)

        # contiguous oracle
        g = H // Hkv
        kk = jnp.repeat(k_ctg, g, axis=2)
        vv = jnp.repeat(v_ctg, g, axis=2)
        s = jnp.einsum("bhd,bkhd->bhk", q, kk) * hd ** -0.5
        valid = jnp.arange(P * bs)[None] < lens[:, None]
        s = jnp.where(valid[:, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ref = jnp.einsum("bhk,bkhd->bhd", p, vv)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ------------------------------------------------- int8 pools + scales
def _quantize_pools(kp, vp):
    qk, sk = quantize_kv(kp)
    qv, sv = quantize_kv(vp)
    return qk, qv, dict(k_scale=sk, v_scale=sv)


@pytest.mark.kv_quant
class TestQuantizedPagedAttention:
    """int8 pools + per-(token, head) scales, dequantized inside the kernel:
    every mode (full / ring / verify / ring-verify) must match the quantized
    reference, and the reference with scales must equal the reference run on
    an explicitly dequantized fp32 pool bit-for-bit (the scales are pure
    layout, not new math)."""

    def _full_case(self, k=None):
        B, H, Hkv, hd, N, bs, P = 3, 4, 2, 64, 24, 8, 4
        lens = [1, bs * P, bs + 3]
        if k is None:
            return _random_case(jax.random.PRNGKey(0), B, H, Hkv, hd, N, bs,
                                P, jnp.float32, lens)
        q, kp, vp, tables, lens = _random_case(
            jax.random.PRNGKey(0), B, H, Hkv, hd, N, bs, P, jnp.float32,
            [max(l, k) for l in lens])
        q = jax.random.normal(jax.random.PRNGKey(1), (B, k, H, hd))
        return q, kp, vp, tables, lens

    def _ring_case(self, k=None):
        B, H, Hkv, hd, bs, window = 3, 4, 2, 32, 4, 6
        K = 1 if k is None else k
        R = SP.ring_pages(window, bs, draft=K - 1)
        N = B * R + 2
        lens = [K, 2 * bs + 1, 6 * bs]          # fresh / 2nd page / deep wrap
        q, kp, vp, tables, lens = _random_case(
            jax.random.PRNGKey(2), B, H, Hkv, hd, N, bs, R, jnp.float32,
            lens)
        if k is not None:
            q = jax.random.normal(jax.random.PRNGKey(3), (B, k, H, hd))
        pos = jnp.maximum(lens - 1, 0)
        return q, kp, vp, tables, lens, dict(window=window, positions=pos,
                                             ring_pages=R)

    @pytest.mark.parametrize("mode", ["full", "ring", "verify",
                                      "ring_verify"])
    def test_quant_kernel_vs_quant_ref(self, mode):
        if mode == "full":
            q, kp, vp, tables, lens = self._full_case()
            kw, op, rf = {}, paged_attention, paged_attention_ref
        elif mode == "ring":
            q, kp, vp, tables, lens, kw = self._ring_case()
            op, rf = paged_attention, paged_attention_ref
        elif mode == "verify":
            q, kp, vp, tables, lens = self._full_case(k=4)
            kw, op, rf = {}, paged_attention_verify, paged_attention_verify_ref
        else:
            q, kp, vp, tables, lens, kw = self._ring_case(k=4)
            op, rf = paged_attention_verify, paged_attention_verify_ref
        qk, qv, scales = _quantize_pools(kp, vp)
        out = op(q, qk, qv, tables, lens, **scales, **kw)
        ref = rf(q, qk, qv, tables, lens, **scales, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)

    @pytest.mark.parametrize("mode", ["full", "ring"])
    def test_ref_scales_equals_dequantized_pool(self, mode):
        if mode == "full":
            q, kp, vp, tables, lens = self._full_case()
            kw = {}
        else:
            q, kp, vp, tables, lens, kw = self._ring_case()
        qk, qv, scales = _quantize_pools(kp, vp)
        with_scales = paged_attention_ref(q, qk, qv, tables, lens, **scales,
                                          **kw)
        on_dequant = paged_attention_ref(
            q, dequantize_kv(qk, scales["k_scale"]),
            dequantize_kv(qv, scales["v_scale"]), tables, lens, **kw)
        np.testing.assert_array_equal(np.asarray(with_scales),
                                      np.asarray(on_dequant))

    def test_garbage_blocks_and_scales_masked(self):
        """Stale blocks past seq_len may hold garbage VALUES AND SCALES from
        freed sequences — both must be masked out."""
        B, H, Hkv, hd, N, bs, P = 1, 2, 2, 32, 6, 4, 3
        q, kp, vp, tables, lens = _random_case(
            jax.random.PRNGKey(7), B, H, Hkv, hd, N, bs, P, jnp.float32, [6])
        qk, qv, scales = _quantize_pools(kp, vp)
        out1 = paged_attention(q, qk, qv, tables, lens, **scales)
        qk2 = qk.at[tables[0, 1], 2:].set(127).at[tables[0, 2]].set(127)
        qv2 = qv.at[tables[0, 1], 2:].set(127).at[tables[0, 2]].set(127)
        poisoned = {
            n: s.at[tables[0, 1], 2:].set(1e6).at[tables[0, 2]].set(1e6)
            for n, s in scales.items()}
        for fn in (paged_attention, paged_attention_ref):
            out2 = fn(q, qk2, qv2, tables, lens, **poisoned)
            np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                       atol=1e-6)

    def test_inactive_slot_outputs_zero_quant(self):
        B, H, Hkv, hd, N, bs, P = 2, 4, 2, 32, 8, 4, 2
        q, kp, vp, tables, lens = _random_case(
            jax.random.PRNGKey(4), B, H, Hkv, hd, N, bs, P, jnp.float32,
            [5, 0])
        qk, qv, scales = _quantize_pools(kp, vp)
        for out in (paged_attention(q, qk, qv, tables, lens, **scales),
                    paged_attention_ref(q, qk, qv, tables, lens, **scales)):
            assert bool(jnp.all(out[1] == 0))
            assert bool(jnp.all(jnp.isfinite(out)))
