"""Paged-decode attention: Pallas kernel (interpret mode) vs pure-jnp oracle,
and the oracle vs a contiguous masked-attention reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import paged_attention, paged_attention_ref

pytestmark = pytest.mark.serving

NEG_INF = -1e30


def _random_case(key, B, H, Hkv, hd, N, bs, P, dtype, lens):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    q = jax.random.normal(k1, (B, H, hd), jnp.float32).astype(dtype)
    kp = jax.random.normal(k2, (N, bs, Hkv, hd), jnp.float32).astype(dtype)
    vp = jax.random.normal(k3, (N, bs, Hkv, hd), jnp.float32).astype(dtype)
    # distinct random blocks per sequence (no aliasing between sequences)
    perm = jax.random.permutation(k4, N)[:B * P]
    tables = perm.reshape(B, P).astype(jnp.int32)
    return q, kp, vp, tables, jnp.asarray(lens, jnp.int32)


class TestPagedAttentionSweep:
    @pytest.mark.parametrize("H,Hkv,hd", [(4, 4, 32), (4, 2, 64), (8, 1, 32)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_kernel_vs_ref(self, H, Hkv, hd, dtype):
        B, N, bs, P = 3, 24, 8, 4
        # lengths cross page boundaries, fill exactly, and include a mid-page
        lens = [1, bs * P, bs + 3]
        q, kp, vp, tables, lens = _random_case(
            jax.random.PRNGKey(H * 100 + hd), B, H, Hkv, hd, N, bs, P,
            dtype, lens)
        out = paged_attention(q, kp, vp, tables, lens)
        ref = paged_attention_ref(q, kp, vp, tables, lens)
        tol = 2e-5 if dtype == jnp.float32 else 0.08
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=tol)

    def test_inactive_slot_outputs_zero(self):
        B, H, Hkv, hd, N, bs, P = 2, 4, 2, 32, 8, 4, 2
        q, kp, vp, tables, lens = _random_case(
            jax.random.PRNGKey(0), B, H, Hkv, hd, N, bs, P, jnp.float32,
            [5, 0])
        for out in (paged_attention(q, kp, vp, tables, lens),
                    paged_attention_ref(q, kp, vp, tables, lens)):
            assert bool(jnp.all(out[1] == 0))
            assert bool(jnp.all(jnp.isfinite(out)))

    def test_garbage_beyond_seq_len_is_masked(self):
        """Blocks past seq_len may contain stale data from freed sequences."""
        B, H, Hkv, hd, N, bs, P = 1, 2, 2, 32, 6, 4, 3
        key = jax.random.PRNGKey(7)
        q, kp, vp, tables, lens = _random_case(
            key, B, H, Hkv, hd, N, bs, P, jnp.float32, [6])
        out1 = paged_attention(q, kp, vp, tables, lens)
        # poison everything at/after position 6 in this sequence's pages
        kp2 = kp.at[tables[0, 1], 2:].set(1e4).at[tables[0, 2]].set(1e4)
        vp2 = vp.at[tables[0, 1], 2:].set(1e4).at[tables[0, 2]].set(1e4)
        out2 = paged_attention(q, kp2, vp2, tables, lens)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)

    def test_ref_matches_contiguous_attention(self):
        """Scatter a contiguous sequence into pages -> paged ref equals plain
        masked decode attention over the contiguous K/V."""
        B, H, Hkv, hd, bs, P = 2, 4, 2, 16, 4, 4
        N = B * P
        L = [11, 7]
        key = jax.random.PRNGKey(3)
        k1, k2, k3 = jax.random.split(key, 3)
        q = jax.random.normal(k1, (B, H, hd))
        k_ctg = jax.random.normal(k2, (B, P * bs, Hkv, hd))
        v_ctg = jax.random.normal(k3, (B, P * bs, Hkv, hd))
        tables = jnp.arange(N, dtype=jnp.int32).reshape(B, P)
        kp = k_ctg.reshape(B * P, bs, Hkv, hd)
        vp = v_ctg.reshape(B * P, bs, Hkv, hd)
        lens = jnp.asarray(L, jnp.int32)
        out = paged_attention_ref(q, kp, vp, tables, lens)

        # contiguous oracle
        g = H // Hkv
        kk = jnp.repeat(k_ctg, g, axis=2)
        vv = jnp.repeat(v_ctg, g, axis=2)
        s = jnp.einsum("bhd,bkhd->bhk", q, kk) * hd ** -0.5
        valid = jnp.arange(P * bs)[None] < lens[:, None]
        s = jnp.where(valid[:, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ref = jnp.einsum("bhk,bkhd->bhd", p, vv)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
