"""§5.3 pipeline tests: GPipe schedule correctness + bubble model."""
import pytest

from repro.core import costmodel as cm
from repro.core.pipeline import num_pipeline_rounds
from conftest import run_multidev


class TestBubbleModel:
    def test_rounds(self):
        assert num_pipeline_rounds(4, 8) == 11

    def test_bubble_matches_rounds(self):
        """bubble = idle work / total work = (S−1)/(S−1+M)."""
        S, M = 4, 8
        rounds = num_pipeline_rounds(S, M)
        busy = M  # each stage works M of the rounds
        assert cm.pipeline_bubble_fraction(S, M) == pytest.approx(
            (rounds - busy) / rounds)


@pytest.mark.slow
class TestPipelineCorrectness:
    def test_matches_sequential(self):
        run_multidev("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core.pipeline import pipeline_forward
            mesh = jax.make_mesh((4,), ('stage',))
            key = jax.random.PRNGKey(0)
            W = jax.random.normal(key, (4, 16, 16)) * 0.3
            b = jax.random.normal(jax.random.PRNGKey(1), (4, 16)) * 0.1
            params = {'w': W, 'b': b}
            def stage_fn(p, x):
                return jnp.tanh(x @ p['w'][0] + p['b'][0]) \
                    if p['w'].ndim == 3 else jnp.tanh(x @ p['w'] + p['b'])
            M, mb = 8, 4
            x = jax.random.normal(jax.random.PRNGKey(2), (M, mb, 16))
            out = pipeline_forward(stage_fn, params, x, mesh)
            ref = x
            for s in range(4):
                ref = jnp.tanh(ref @ W[s] + b[s])
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-4, atol=2e-5)
            print('PASS')
        """, devices=4)
