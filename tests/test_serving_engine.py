"""Continuous-batching engine: block-pool invariants, chunked prefill,
end-to-end equality with the legacy serving path, defrag, and the Pallas
kernel route. All CPU (`-m serving` smoke subset; interpret-mode Pallas)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serving import serve
from repro.serving.engine import BlockPool, BlockPoolError, Engine, EngineConfig

pytestmark = pytest.mark.serving


# ------------------------------------------------------------------ BlockPool
class TestBlockPool:
    def test_alloc_free_roundtrip(self):
        p = BlockPool(8, 4)
        got = p.alloc("a", 3)
        assert len(got) == len(set(got)) == 3 and p.num_free == 5
        p.alloc("b", 5)
        assert p.num_free == 0 and p.utilization == 1.0
        assert not p.can_alloc(1)
        p.free_seq("a")
        assert p.num_free == 3
        p.free_seq("b")
        assert p.num_free == 8

    def test_double_free_raises(self):
        p = BlockPool(4, 4)
        p.alloc("a", 2)
        p.free_seq("a")
        with pytest.raises(BlockPoolError):
            p.free_seq("a")

    def test_over_alloc_raises(self):
        p = BlockPool(4, 4)
        with pytest.raises(BlockPoolError):
            p.alloc("a", 5)

    def test_no_block_owned_twice(self):
        p = BlockPool(16, 4)
        owned = p.alloc("a", 5) + p.alloc("b", 7) + p.alloc("a", 4)
        assert len(owned) == len(set(owned)) == 16

    def test_blocks_for(self):
        p = BlockPool(8, 4)
        assert [p.blocks_for(n) for n in (1, 4, 5, 8, 9)] == [1, 1, 2, 2, 3]

    def test_defragment_compacts_and_preserves_ownership(self):
        p = BlockPool(10, 4)
        p.alloc("a", 3)
        p.alloc("b", 3)
        p.free_seq("a")                        # holes at the front
        before = p.table("b")
        src = p.defragment()
        after = p.table("b")
        assert after == [0, 1, 2]              # compacted to the front
        # permutation maps old contents to new slots: new[i] = old[src[i]]
        assert [int(src[i]) for i in after] == before
        assert sorted(src.tolist()) == list(range(10))
        assert p.num_free == 7
        p.alloc("c", 7)                        # free list is consistent
        assert p.num_free == 0


# ------------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def cfg():
    return ModelConfig(name="eng-t", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                       vocab_size=50, loss_chunk=16, attn_chunk=16,
                       remat=False, dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(cfg, jax.random.PRNGKey(0))


def _engine(cfg, params, **kw):
    base = dict(block_size=4, num_blocks=64, max_blocks_per_seq=8,
                max_slots=4, prefill_chunk=8)
    base.update(kw)
    return Engine(cfg, params, EngineConfig(**base))


MIXED_LENS = (3, 7, 12, 5, 20, 9, 4, 15)
MIXED_NEWS = (4, 6, 3, 8, 5, 7, 2, 6)


def _mixed_requests(vocab=50, seed=42):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=L).astype(np.int32)
            for L in MIXED_LENS], list(MIXED_NEWS)


# ------------------------------------------------------------------ end-to-end
class TestEngineE2E:
    def test_mixed_lengths_staggered_bit_identical_to_serve(self, cfg, params):
        """Acceptance: N=8 staggered mixed-length requests through step()
        produce greedy outputs bit-identical to serve.generate."""
        prompts, news = _mixed_requests()
        eng = _engine(cfg, params)
        rids = []
        for p, mn in zip(prompts, news):
            rids.append(eng.add_request(p, mn))
            eng.step()                          # staggered arrivals
        outs = eng.drain()
        assert len(outs) == len(prompts)
        for rid, p, mn in zip(rids, prompts, news):
            ref = np.asarray(serve.generate(
                cfg, params, jnp.asarray(p)[None], max_new=mn,
                temperature=0.0))[0]
            np.testing.assert_array_equal(outs[rid], ref)

    def test_no_block_leak_after_drain(self, cfg, params):
        prompts, news = _mixed_requests(seed=1)
        eng = _engine(cfg, params)
        for p, mn in zip(prompts, news):
            eng.add_request(p, mn)
        eng.drain()
        assert eng.block_pool.num_free == eng.ecfg.num_blocks
        assert not eng.scheduler.running and not eng.scheduler.waiting

    def test_chunked_prefill_long_prompt(self, cfg, params):
        """Prompt much longer than prefill_chunk prefills over several steps
        and still matches the reference."""
        rng = np.random.default_rng(3)
        prompt = rng.integers(0, 50, size=21).astype(np.int32)
        eng = _engine(cfg, params, prefill_chunk=4)
        rid = eng.add_request(prompt, 5)
        outs = eng.drain()
        assert eng.stats["prefill_chunks"] == 6   # ceil(21/4)
        ref = np.asarray(serve.generate(
            cfg, params, jnp.asarray(prompt)[None], max_new=5,
            temperature=0.0))[0]
        np.testing.assert_array_equal(outs[rid], ref)

    def test_kernel_impl_matches_ref_impl(self, cfg, params):
        prompts, news = _mixed_requests(seed=5)
        outs = {}
        for impl in ("ref", "kernel"):
            eng = _engine(cfg, params, attn_impl=impl, max_slots=2)
            rids = [eng.add_request(p, mn)
                    for p, mn in zip(prompts[:3], news[:3])]
            res = eng.drain()
            outs[impl] = [res[r] for r in rids]
        for a, b in zip(outs["ref"], outs["kernel"]):
            np.testing.assert_array_equal(a, b)

    def test_defragment_mid_flight_preserves_outputs(self, cfg, params):
        prompts, news = _mixed_requests(seed=7)
        eng = _engine(cfg, params)
        rids = [eng.add_request(p, mn) for p, mn in zip(prompts, news)]
        for _ in range(4):
            eng.step()
        eng.defragment()                        # live sequences get remapped
        for _ in range(3):
            eng.step()
        eng.defragment()
        outs = eng.drain()
        for rid, p, mn in zip(rids, prompts, news):
            ref = np.asarray(serve.generate(
                cfg, params, jnp.asarray(p)[None], max_new=mn,
                temperature=0.0))[0]
            np.testing.assert_array_equal(outs[rid], ref)

    def test_admission_respects_block_budget(self, cfg, params):
        """Pool with room for ~1 sequence: requests are served one at a time
        but all complete."""
        prompts, news = _mixed_requests(seed=9)
        eng = _engine(cfg, params, num_blocks=8, max_slots=4)
        rids = [eng.add_request(p, mn) for p, mn in zip(prompts[:4], news[:4])]
        outs = eng.drain()
        assert sorted(outs) == sorted(rids)
        assert eng.block_pool.num_free == 8

    def test_stop_token_and_temperature_paths(self, cfg, params):
        prompts, _ = _mixed_requests(seed=11)
        eng = _engine(cfg, params)
        r1 = eng.add_request(prompts[0], 5, temperature=1.0,
                             key=jax.random.PRNGKey(0))
        r2 = eng.add_request(prompts[1], 20, stop_token=7)
        outs = eng.drain()
        assert outs[r1].shape == (5,)
        assert bool(np.all(outs[r1] >= 0)) and bool(np.all(outs[r1] < 50))
        assert outs[r2][-1] == 7 or outs[r2].shape == (20,)

    def test_oversized_request_rejected(self, cfg, params):
        eng = _engine(cfg, params)
        with pytest.raises(ValueError):
            eng.add_request(np.zeros(100, np.int32), 10)   # > table width


# --------------------------------------------------------------- serve prefill
class TestBatchedPrefill:
    def test_batched_equals_loop_dense(self, cfg, params):
        prompt = jnp.asarray([[1, 2, 3, 4, 7, 9, 11], [5, 6, 7, 8, 2, 3, 4]],
                             jnp.int32)
        a = serve.generate(cfg, params, prompt, max_new=6, temperature=0.0,
                           prefill_mode="batched")
        b = serve.generate(cfg, params, prompt, max_new=6, temperature=0.0,
                           prefill_mode="loop")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_batched_equals_loop_sliding_window(self):
        cfg = ModelConfig(name="eng-s", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                          d_ff=128, vocab_size=50, loss_chunk=16,
                          attn_chunk=16, remat=False, dtype="float32",
                          attention_type="sliding", window_size=4)
        params = T.init_params(cfg, jax.random.PRNGKey(1))
        prompt = jnp.asarray([[1, 2, 3, 4, 7, 9, 11, 13, 2, 5]], jnp.int32)
        a = serve.generate(cfg, params, prompt, max_new=5, temperature=0.0,
                           prefill_mode="batched")
        b = serve.generate(cfg, params, prompt, max_new=5, temperature=0.0,
                           prefill_mode="loop")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_auto_falls_back_for_recurrent_families(self):
        cfg = ModelConfig(name="eng-r", family="ssm", ssm_type="rwkv6",
                          num_layers=2, d_model=64, num_heads=2,
                          num_kv_heads=2, head_dim=32, d_ff=128,
                          vocab_size=50, loss_chunk=16, attn_chunk=16,
                          remat=False, ssm_head_dim=32, dtype="float32")
        assert not T.supports_batched_prefill(cfg)
        params = T.init_params(cfg, jax.random.PRNGKey(2))
        prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        out = serve.generate(cfg, params, prompt, max_new=3, temperature=0.0)
        assert out.shape == (1, 3)
