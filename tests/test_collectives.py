"""§2.5 collective-algorithm tests: correctness on 8 devices (subprocess) and
structural step counts matching the paper's schedules."""
import math

import pytest

from repro.core import collectives as coll
from conftest import run_multidev


class TestScheduleStructure:
    def test_step_counts_match_paper(self):
        """tree 2log2P, butterfly log2P, ring 2(P−1), rabenseifner 2log2P."""
        for P in (2, 4, 8, 16):
            assert coll.schedule_steps("tree", P) == 2 * int(math.log2(P))
            assert coll.schedule_steps("butterfly", P) == int(math.log2(P))
            assert coll.schedule_steps("ring", P) == 2 * (P - 1)
            assert coll.schedule_steps("rabenseifner", P) == 2 * int(math.log2(P))


@pytest.mark.slow
class TestCorrectness8Devices:
    def test_all_algorithms_equal_psum(self):
        run_multidev("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.core.compat import shard_map
            from repro.core import collectives as coll
            mesh = jax.make_mesh((8,), ('x',))
            x = jnp.arange(8*40, dtype=jnp.float32).reshape(8, 40) * 0.01 - 1.0
            expect = np.broadcast_to(np.asarray(x.sum(0)), (8, 40))
            for alg in coll.ALGORITHMS:
                f = shard_map(
                    lambda v: coll.allreduce_sum(v[0], 'x', algorithm=alg)[None],
                    mesh=mesh, in_specs=P('x'), out_specs=P('x'),
                    check_vma=False)
                np.testing.assert_allclose(np.asarray(f(x)), expect,
                                           rtol=1e-5, err_msg=alg)
            print('PASS')
        """)

    def test_ppermute_counts_in_hlo(self):
        """Structural check: the lowered HLO contains exactly the number of
        communication steps the paper's schedule predicts."""
        run_multidev("""
            import jax, jax.numpy as jnp, re
            from jax.sharding import PartitionSpec as P
            from repro.core.compat import shard_map
            from repro.core import collectives as coll
            mesh = jax.make_mesh((8,), ('x',))
            x = jnp.zeros((8, 64), jnp.float32)
            for alg, expected in [('ring', 14), ('butterfly', 3),
                                  ('rabenseifner', 6)]:
                f = shard_map(
                    lambda v: coll.allreduce_sum(v[0], 'x', algorithm=alg)[None],
                    mesh=mesh, in_specs=P('x'), out_specs=P('x'),
                    check_vma=False)
                txt = jax.jit(f).lower(x).as_text()
                n = len(re.findall(r'collective.permute|ppermute', txt))
                # each exchange step may lower to 1 (masked) or 2 (both-way)
                assert expected <= n <= 2 * expected, (alg, n, expected)
            print('PASS')
        """)

    def test_compressed_allreduce_with_error_feedback(self):
        """§6.3 end-to-end: int8-compressed ring allreduce + EF still sums."""
        run_multidev("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.core.compat import shard_map
            from repro.core import collectives as coll
            from repro.core.compression import make_compressor
            mesh = jax.make_mesh((8,), ('x',))
            comp = make_compressor('int8')
            key = jax.random.PRNGKey(0)
            x = jax.random.normal(key, (8, 256)) * 0.01
            def f(v):
                sent = comp(v[0], jax.random.PRNGKey(1))
                return coll.allreduce_sum(sent, 'x', algorithm='ring')[None]
            g = shard_map(f, mesh=mesh, in_specs=P('x'), out_specs=P('x'),
                          check_vma=False)
            out = np.asarray(g(x))
            expect = np.asarray(x.sum(0))
            rel = np.linalg.norm(out[0] - expect) / np.linalg.norm(expect)
            assert rel < 0.05, rel
            print('PASS')
        """)
