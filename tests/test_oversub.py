"""Oversubscription subsystem: optimistic admission, on-demand block growth,
victim preemption, SLO-aware scheduling — plus the preempt/resume telemetry
rules and a BlockPool append/evict property harness.

The load-bearing guarantee is bit-identical greedy output across forced
preemption: a preempted request re-prefills ``prompt + generated`` over the
identical KV (or restores a recurrent-slab snapshot), so the continuation
argmaxes exactly as the never-preempted run. The soak tests force every
request through at least one evict/resume cycle per model family and diff
against ``serve.generate``.

All CPU. Select with `pytest -m oversub` (subset of `-m serving`).
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serving import serve
from repro.serving.engine import (BlockPool, Engine, EngineConfig,
                                  KVQuantConfig, OversubConfig, SLOPolicy,
                                  prefix_hashes)
from repro.serving.engine.scheduler import DECODING, Request
from repro.serving.telemetry import (Event, TelemetryError, derive_timeline,
                                     validate_order)

pytestmark = [pytest.mark.serving, pytest.mark.oversub]


# ------------------------------------------------------------------ SLOPolicy
def _req(rid, priority=0, generated=0):
    r = Request(rid=rid, prompt=np.zeros(4, np.int32), max_new=8,
                priority=priority)
    r.out_tokens = [0] * generated
    return r


class TestSLOPolicy:
    def test_config_validation(self):
        with pytest.raises(ValueError):
            OversubConfig(admit_watermark=0.0)
        with pytest.raises(ValueError):
            OversubConfig(admit_watermark=1.5)
        with pytest.raises(ValueError):
            OversubConfig(step_ewma=0.0)

    def test_protection_total_order(self):
        """Strongest first: class, then invested work, then age — and
        victim_order is its exact reverse."""
        reqs = [_req(0, priority=1, generated=5),
                _req(1, priority=0, generated=0),
                _req(2, priority=1, generated=9),
                _req(3, priority=1, generated=9)]
        by_protection = sorted(reqs, key=SLOPolicy.protection_key)
        assert [r.rid for r in by_protection] == [1, 2, 3, 0]
        assert [r.rid for r in SLOPolicy.victim_order(reqs)] == [0, 3, 2, 1]

    def test_pick_victim_priority_filter(self):
        pol = SLOPolicy(OversubConfig())
        reqs = [_req(0, priority=0), _req(1, priority=1, generated=3),
                _req(2, priority=1)]
        # unrestricted: weakest overall (class 1, least invested, youngest)
        assert pol.pick_victim(reqs).rid == 2
        # a class-0 head may only evict STRICTLY weaker classes
        assert pol.pick_victim(reqs, max_priority=0).rid == 2
        # a class-1 head finds no strictly-weaker victim
        assert pol.pick_victim(reqs, max_priority=1) is None
        assert pol.pick_victim([], max_priority=None) is None

    def test_may_admit_watermark(self):
        pol = SLOPolicy(OversubConfig(admit_watermark=0.9))
        pool = BlockPool(10, 4)
        pool.alloc("a", 6)                       # util 0.6, 4 free
        assert pol.may_admit(pool, 2, 0, running=1)        # 8 used <= 9
        assert pol.may_admit(pool, 2, 1, running=1)        # 9 used <= 9
        assert not pol.may_admit(pool, 4, 0, running=1)    # 10 used > 9
        assert not pol.may_admit(pool, 5, 0, running=1)    # doesn't even fit
        assert not pol.may_admit(pool, 5, 0, running=0)    # idle can't conjure
        assert pol.may_admit(pool, 4, 0, running=0)        # idle bypass

    def test_note_step_ewma(self):
        pol = SLOPolicy(OversubConfig(step_ewma=0.5))
        assert pol.step_ewma_s is None
        pol.note_step(0.1)
        assert pol.step_ewma_s == pytest.approx(0.1)
        pol.note_step(0.3)
        assert pol.step_ewma_s == pytest.approx(0.2)

    def test_allow_prefill_rules(self):
        pol = SLOPolicy(OversubConfig(ttft_slo_s=0.5, tpot_slo_s=0.05))
        # nothing decoding: always prefill (deferring would deadlock)
        assert pol.allow_prefill(head_wait_s=None, decoding=0, pool_util=0.99)
        # pool over the watermark: decode-only
        assert not pol.allow_prefill(head_wait_s=0.01, decoding=2,
                                     pool_util=0.95)
        # ... unless the queue head is past the TTFT target
        assert pol.allow_prefill(head_wait_s=0.6, decoding=2, pool_util=0.95)
        # TPOT pressure defers prefill
        pol.note_step(0.2)
        assert not pol.allow_prefill(head_wait_s=0.01, decoding=2,
                                     pool_util=0.1)
        assert pol.allow_prefill(head_wait_s=0.6, decoding=2, pool_util=0.1)
        # healthy: prefill through
        calm = SLOPolicy(OversubConfig())
        calm.note_step(0.001)
        assert calm.allow_prefill(head_wait_s=0.01, decoding=2, pool_util=0.5)


# -------------------------------------------- pool append/evict property test
@pytest.mark.parametrize("seed", range(120))
def test_blockpool_append_evict_episode(seed):
    """Seeded randomized episodes of the oversubscription pool life:
    optimistic admit (small alloc), per-step append, register-then-evict
    victim rollback, and cached-prefix revival — `BlockPool.check()` plus
    shadow tables after every operation."""
    rng = random.Random(seed)
    bs = rng.choice([2, 4])
    num_blocks = rng.choice([8, 12, 16])
    pool = BlockPool(num_blocks, bs)
    owners = {}                                   # rid -> expected table
    tokens = {}                                   # rid -> token stream
    base = [rng.randrange(5) for _ in range(3 * bs)]
    next_rid = 0

    for _ in range(rng.randint(40, 80)):
        op = rng.random()
        if op < 0.35:                             # optimistic admit
            keep = rng.randrange(0, 3 * bs + 1)
            tail = [rng.randrange(5) for _ in range(rng.randint(1, bs))]
            toks = base[:keep] + tail
            hashes = prefix_hashes(np.asarray(toks, np.int32), bs)
            matched = pool.match_prefix(hashes)
            if matched and len(matched) * bs == len(toks):
                matched = matched[:-1]            # CoW rule: keep a tail
            need = pool.blocks_for(len(toks) + 1)  # prompt + first write
            if pool.admit_feasible(matched, need - len(matched)):
                assert pool.revive_count(matched) == sum(
                    1 for b in matched if pool._ref[b] == 0)
                rid = next_rid
                next_rid += 1
                if matched:
                    pool.share(rid, matched)
                fresh = pool.alloc(rid, need - len(matched))
                owners[rid] = list(matched) + fresh
                tokens[rid] = toks
                row = pool.table(rid)
                for i in range(len(matched), len(hashes)):
                    pool.register(rid, row[i], hashes[i])
        elif op < 0.65 and owners:                # decode growth: append
            rid = rng.choice(sorted(owners))
            # multi-block appends in ONE call: a speculative verify step can
            # commit up to k tokens at once, so growth may need several
            # blocks per step, all-or-nothing
            n = rng.randint(1, 4)
            if pool.can_alloc(n):
                fresh = pool.append(rid, n)
                assert len(fresh) == n == len(set(fresh))
                assert not (set(fresh)
                            & {b for t in owners.values() for b in t})
                owners[rid].extend(fresh)
                tokens[rid] = tokens[rid] + [rng.randrange(5)
                                             for _ in range(n * bs)]
            else:
                before = list(pool.table(rid))
                free_before = pool.num_free
                with pytest.raises(Exception):
                    pool.append(rid, n)
                # failed append mutates nothing: no partial block grants
                assert pool.table(rid) == before
                assert pool.num_free == free_before
        elif op < 0.90 and owners:                # victim: register then evict
            rid = rng.choice(sorted(owners))
            hashes = prefix_hashes(np.asarray(tokens[rid], np.int32), bs)
            row = pool.table(rid)
            for i, h in zip(range(len(row)), hashes):
                pool.register(rid, row[i], h)     # first writer wins / no-op
            pool.evict_seq(rid)
            del owners[rid], tokens[rid]
            with pytest.raises(Exception):        # double-evict raises
                pool.evict_seq(rid)
        else:                                     # error probes
            with pytest.raises(Exception):
                pool.append("no-such-seq", 1)     # append needs an owner
            with pytest.raises(Exception):
                pool.alloc("probe", pool.num_free + 1)
            assert "probe" not in pool._owned

        pool.check()
        for rid, expect in owners.items():
            assert pool.table(rid) == expect
        assert (pool.num_free
                == num_blocks - len({b for t in owners.values() for b in t}))

    for rid in sorted(owners):
        pool.evict_seq(rid)
    pool.drop_cache()
    pool.check()
    assert pool.num_free == num_blocks


# --------------------------------------------------- telemetry lifecycle rules
def _stream(*names, t0=0.0):
    return [Event(t0 + i, 1, n, None) for i, n in enumerate(names)]


class TestPreemptTelemetryRules:
    def test_preempt_resume_cycle_valid(self):
        validate_order(_stream(
            "arrive", "admit", "prefill_chunk", "first_token", "decode_token",
            "preempt", "resume", "prefix_hit", "prefill_chunk", "decode_token",
            "finish"))

    def test_stream_may_end_evicted(self):
        validate_order(_stream("arrive", "admit", "first_token", "preempt"))

    def test_nothing_but_resume_after_preempt(self):
        with pytest.raises(TelemetryError):
            validate_order(_stream("arrive", "admit", "first_token",
                                   "preempt", "decode_token"))

    def test_resume_without_preempt_rejected(self):
        with pytest.raises(TelemetryError):
            validate_order(_stream("arrive", "admit", "resume"))

    def test_preempt_before_admit_rejected(self):
        with pytest.raises(TelemetryError):
            validate_order(_stream("arrive", "preempt"))

    def test_first_token_stays_one_shot_across_segments(self):
        with pytest.raises(TelemetryError):
            validate_order(_stream(
                "arrive", "admit", "first_token", "preempt", "resume",
                "prefill_chunk", "first_token"))

    def test_derived_preempted_time(self):
        tl = derive_timeline(_stream(
            "arrive", "admit", "first_token", "preempt", "resume",
            "decode_token", "preempt", "resume", "finish"))
        assert tl["preempts"] == 2
        assert tl["preempted_s"] == pytest.approx(2.0)   # two 1s gaps
        open_tl = derive_timeline(_stream(
            "arrive", "admit", "preempt"))               # ends evicted
        assert open_tl["preempts"] == 1


# ------------------------------------------------------------------- fixtures
def _model_cfg(family):
    base = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                head_dim=16, d_ff=128, vocab_size=50, loss_chunk=16,
                attn_chunk=16, remat=False, dtype="float32")
    if family == "full":
        return ModelConfig(name="ov-full", family="dense", **base)
    if family == "sliding":
        return ModelConfig(name="ov-sliding", family="dense",
                           attention_type="sliding", window_size=4, **base)
    if family == "ssm":
        return ModelConfig(name="ov-ssm", family="ssm", ssm_type="rwkv6",
                           ssm_head_dim=16, **base)
    if family == "hybrid":
        return ModelConfig(name="ov-hybrid", family="hybrid",
                           hybrid_ssm_per_attn=1, ssm_state_dim=8,
                           ssm_head_dim=16, **base)
    raise ValueError(family)


@pytest.fixture(scope="module", params=["full", "sliding", "ssm", "hybrid"])
def fam_setup(request):
    cfg = _model_cfg(request.param)
    return request.param, cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def _engine(cfg, params, **kw):
    base = dict(block_size=4, num_blocks=64, max_blocks_per_seq=8,
                max_slots=4, prefill_chunk=8, oversub=OversubConfig())
    base.update(kw)
    return Engine(cfg, params, EngineConfig(**base))


def _ref(cfg, params, prompt, max_new, kv_quant=None):
    return np.asarray(serve.generate(cfg, params, jnp.asarray(prompt)[None],
                                     max_new=max_new, temperature=0.0,
                                     kv_quant=kv_quant))[0]


def _prompts(n, seed=0, lo=3, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 50, size=int(s)).astype(np.int32)
            for s in rng.integers(lo, hi, size=n)]


# --------------------------------------------------- forced-preemption soak
class TestForcedPreemptionSoak:
    def test_every_request_evicted_once_bit_identical(self, fam_setup):
        """Each request is force-preempted at a different decode depth, then
        the engine drains: greedy outputs must match `serve.generate`
        bit-for-bit, and every telemetry stream must satisfy the segmented
        lifecycle rules. (ssm runs the snapshot-restore path; sliding and
        hybrid recompute by re-prefill; full re-aliases its registered
        blocks.)"""
        family, cfg, params = fam_setup
        eng = _engine(cfg, params)
        prompts, max_new = _prompts(4, seed=1), 10
        rids = [eng.add_request(p, max_new) for p in prompts]
        pending = list(rids)
        steps = 0
        while pending and steps < 200:
            eng.step()
            steps += 1
            for rid in list(pending):
                req = eng.requests[rid]
                # vary eviction depth: rid k falls after k+1 generated tokens
                depth = rids.index(rid) + 1
                if req.state == DECODING and len(req.out_tokens) >= depth:
                    assert eng.preempt_request(rid)
                    pending.remove(rid)
        assert not pending, "not every request reached its eviction point"
        outs = eng.drain()
        for rid, p in zip(rids, prompts):
            np.testing.assert_array_equal(
                outs[rid], _ref(cfg, params, p, max_new),
                err_msg=f"family={family} rid={rid}")
        assert eng.stats["preemptions"] >= len(rids)
        assert eng.stats["resumes"] >= len(rids)
        for rid in rids:
            evs = eng.telemetry.tracer.request_events(rid)
            validate_order(evs)
            assert derive_timeline(evs)["preempts"] == eng.requests[rid].preempts
        assert eng.block_pool.num_free == eng.ecfg.num_blocks
        eng.block_pool.check()

    @pytest.mark.kv_quant
    def test_quantized_kv_forced_preemption_bit_identical(self, fam_setup):
        """The same forced-eviction soak with int8 paged KV: rollback and
        resume re-quantize the SAME token values the dense quantized
        reference stores (nearest rounding is deterministic), so greedy
        outputs still match `serve.generate(kv_quant=...)` bit-for-bit and
        the decode step stays at its single AOT-warmed variant."""
        family, cfg, params = fam_setup
        kvq = KVQuantConfig()
        eng = _engine(cfg, params, kv_quant=kvq)
        prompts, max_new = _prompts(4, seed=3), 10
        rids = [eng.add_request(p, max_new) for p in prompts]
        pending, steps = list(rids), 0
        while pending and steps < 200:
            eng.step()
            steps += 1
            for rid in list(pending):
                req = eng.requests[rid]
                if (req.state == DECODING
                        and len(req.out_tokens) >= rids.index(rid) + 1):
                    assert eng.preempt_request(rid)
                    pending.remove(rid)
        assert not pending, "not every request reached its eviction point"
        outs = eng.drain()
        for rid, p in zip(rids, prompts):
            np.testing.assert_array_equal(
                outs[rid], _ref(cfg, params, p, max_new, kv_quant=kvq),
                err_msg=f"family={family} rid={rid}")
        assert eng.stats["preemptions"] >= len(rids)
        assert eng.telemetry.recompiles.variants().get("decode") == 1
        assert eng.block_pool.num_free == eng.ecfg.num_blocks
        eng.block_pool.check()

    def test_preempt_while_prefilling(self, fam_setup):
        """Eviction mid-prefill (before any token): the rollback unit is the
        prefilled prefix only; resume completes prefill and the first token
        is still recorded exactly once."""
        family, cfg, params = fam_setup
        eng = _engine(cfg, params, prefill_chunk=4)
        prompt = _prompts(1, seed=5, lo=10, hi=13)[0]
        rid = eng.add_request(prompt, 6)
        eng.step()                                 # one 4-token chunk in
        req = eng.requests[rid]
        assert req.state != DECODING and 0 < req.prefilled < req.prefill_len
        assert eng.preempt_request(rid)
        outs = eng.drain()
        np.testing.assert_array_equal(outs[rid], _ref(cfg, params, prompt, 6),
                                      err_msg=f"family={family}")
        validate_order(eng.telemetry.tracer.request_events(rid))

    def test_conservative_mode_forced_preemption(self, fam_setup):
        """`preempt_request` works without an OversubConfig too (ops hook):
        the conservative scheduler re-reserves the full span on resume and
        outputs stay bit-identical."""
        family, cfg, params = fam_setup
        eng = _engine(cfg, params, oversub=None)
        prompt = _prompts(1, seed=7)[0]
        rid = eng.add_request(prompt, 8)
        while eng.requests[rid].state != DECODING:
            eng.step()
        eng.step()
        assert eng.preempt_request(rid)
        assert not eng.preempt_request(rid)        # already WAITING
        outs = eng.drain()
        np.testing.assert_array_equal(outs[rid], _ref(cfg, params, prompt, 8),
                                      err_msg=f"family={family}")


# ----------------------------------------------- pressure + policy behaviors
class TestOversubEngine:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = _model_cfg("full")
        return cfg, T.init_params(cfg, jax.random.PRNGKey(0))

    def test_natural_pressure_preempts_and_stays_exact(self, setup):
        """Tiny pool + optimistic admission: preemption must occur
        organically (append failures), and every output still matches
        `serve.generate`."""
        cfg, params = setup
        eng = _engine(cfg, params, num_blocks=20, max_slots=6,
                      oversub=OversubConfig(admit_watermark=0.8))
        prompts = _prompts(12, seed=3)
        rids = [eng.add_request(p, 12, priority=i % 2)
                for i, p in enumerate(prompts)]
        outs = eng.drain()
        for rid, p in zip(rids, prompts):
            np.testing.assert_array_equal(outs[rid], _ref(cfg, params, p, 12))
        assert eng.stats["preemptions"] > 0
        assert eng.stats["block_appends"] > 0
        for rid in rids:
            validate_order(eng.telemetry.tracer.request_events(rid))
        assert eng.block_pool.num_free == eng.ecfg.num_blocks
        eng.block_pool.check()

    def test_optimistic_admits_more_than_full_reservation(self, setup):
        """The core oversubscription claim at engine level: a pool too small
        to co-reserve every span still runs all slots concurrently under
        optimistic admission."""
        cfg, params = setup
        prompts = _prompts(4, seed=9, lo=4, hi=6)
        n_conc = {}
        for name, ov in (("opt", OversubConfig()), ("full", None)):
            eng = _engine(cfg, params, num_blocks=12, max_slots=4,
                          max_blocks_per_seq=8, oversub=ov)
            for p in prompts:
                eng.add_request(p, 20)             # span needs 7-8 blocks
            eng.step()
            n_conc[name] = len(eng.scheduler.running)
            eng.drain()
        assert n_conc["full"] <= 2 < n_conc["opt"] == 4

    def test_priority_preemption_unblocks_head(self, setup):
        """A blocked class-0 head evicts a class-1 victim; the victim resumes
        and both finish bit-identically."""
        cfg, params = setup
        eng = _engine(cfg, params, num_blocks=8, max_slots=2,
                      max_blocks_per_seq=8)
        lo_p, hi_p = _prompts(2, seed=11, lo=8, hi=10)
        lo = eng.add_request(lo_p, 16, priority=1)
        while eng.requests[lo].state != DECODING:
            eng.step()
        for _ in range(4):
            eng.step()
        hi = eng.add_request(hi_p, 16, priority=0)
        outs = eng.drain()
        assert eng.requests[lo].preempts >= 1      # victimized by the head
        assert eng.stats["preemptions"] >= 1
        np.testing.assert_array_equal(outs[lo], _ref(cfg, params, lo_p, 16))
        np.testing.assert_array_equal(outs[hi], _ref(cfg, params, hi_p, 16))

    def test_temperature_sampling_exact_across_preemption(self, setup):
        """Sampled decoding survives preemption exactly: the PRNG key state
        rides on the host request, so the split sequence — and therefore
        every sampled token — is identical with and without eviction."""
        cfg, params = setup
        prompt = _prompts(1, seed=13)[0]
        outs = {}
        for forced in (False, True):
            eng = _engine(cfg, params)
            rid = eng.add_request(prompt, 10, temperature=0.8,
                                  key=jax.random.PRNGKey(42))
            if forced:
                while eng.requests[rid].state != DECODING:
                    eng.step()
                for _ in range(3):
                    eng.step()
                assert eng.preempt_request(rid)
            outs[forced] = eng.drain()[rid]
        np.testing.assert_array_equal(outs[False], outs[True])

    def test_stats_and_timeline_accounting(self, setup):
        """preempts/resumes counters, per-request preempt counts, and the
        derived preempted-time all agree after a forced cycle."""
        cfg, params = setup
        eng = _engine(cfg, params)
        rid = eng.add_request(_prompts(1, seed=15)[0], 8)
        while eng.requests[rid].state != DECODING:
            eng.step()
        eng.step()
        eng.preempt_request(rid)
        eng.drain()
        assert eng.stats["preemptions"] == 1
        assert eng.stats["resumes"] == 1
        tl = eng.telemetry.request_timeline(rid)
        assert tl["preempts"] == 1 == eng.requests[rid].preempts
        assert tl["preempted_s"] > 0.0
        assert tl["finish"] is not None
