"""§2.5 collective cost-model tests — the paper's formulas and bounds."""
import math

import pytest
hypothesis = pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import costmodel as cm

P_pow2 = st.sampled_from([2, 4, 8, 16, 64, 256])
msg = st.integers(min_value=1, max_value=10**9)


class TestPaperFormulas:
    @given(P=P_pow2, m=msg)
    @settings(max_examples=60, deadline=None)
    def test_butterfly_half_of_tree(self, P, m):
        """T_tree = 2·log2(P)(L+γmG); T_bfly is exactly half."""
        L, G = 1e-6, 1e-9
        assert cm.t_tree(P, m, L, G) == pytest.approx(2 * cm.t_butterfly(P, m, L, G))

    @given(P=P_pow2, m=msg)
    @settings(max_examples=60, deadline=None)
    def test_rabenseifner_achieves_lower_bound_bandwidth(self, P, m):
        """'This algorithm achieves the lower bound' — for the bandwidth term
        (latency term is 2× the bound's)."""
        L, G = 0.0, 1e-9
        assert cm.t_rabenseifner(P, m, L, G) == pytest.approx(
            cm.t_lower_bound(P, m, L, G))

    @given(P=P_pow2, m=msg)
    @settings(max_examples=80, deadline=None)
    def test_no_algorithm_beats_lower_bound(self, P, m):
        L, G = 1e-6, 1e-9
        lb = cm.t_lower_bound(P, m, L, G)
        for f in (cm.t_tree, cm.t_butterfly, cm.t_pipeline, cm.t_rabenseifner):
            assert f(P, m, L, G) >= lb * (1 - 1e-12)

    def test_regime_crossover(self):
        """§2.5: butterfly near-optimal for small γm; pipeline bandwidth-
        optimal for large γm and small P."""
        L, G = 1e-6, 1e-10
        small = cm.best_allreduce(256, 64, L, G)[0]
        large = cm.best_allreduce(4, 10**9, L, G)[0]
        assert small == "butterfly"
        assert large in ("ring", "rabenseifner")

    def test_ps_equals_tree(self):
        """§6.2: PS communication ≡ reduce-then-broadcast = T_tree."""
        assert cm.t_parameter_server(64, 10**6, 1e-6, 1e-9) == \
            cm.t_tree(64, 10**6, 1e-6, 1e-9)


class TestParallelismVolumes:
    def test_hybrid_beats_pure_dp_for_fc_heavy(self):
        """§5.4 'one weird trick': AlexNet-like nets (few conv params, huge FC
        params) communicate less with hybrid DP(conv)+MP(fc)."""
        n_conv, n_fc = 3.7e6, 58.6e6          # AlexNet split
        batch, fc_width = 256, 4096
        dp = cm.dp_comm_bytes(n_conv + n_fc)
        hybrid = cm.hybrid_comm_bytes(n_conv, n_fc, batch, fc_width * 2)
        assert hybrid < dp / 5

    @given(S=st.integers(2, 64), M=st.integers(1, 512))
    @settings(max_examples=50, deadline=None)
    def test_pipeline_bubble(self, S, M):
        f = cm.pipeline_bubble_fraction(S, M)
        assert 0 <= f < 1
        # more microbatches → smaller bubble (§5.3)
        assert cm.pipeline_bubble_fraction(S, M + 1) <= f


class TestRoofline:
    def test_terms_and_dominance(self):
        t = cm.roofline_terms(1e18, 1e15, 1e13, chips=256)
        assert t["compute_s"] == pytest.approx(1e18 / (256 * 197e12))
        assert cm.dominant_term({"compute_s": 3, "memory_s": 1, "collective_s": 2}) \
            == "compute_s"

    def test_model_flops(self):
        assert cm.model_flops(1e9, 1e6) == 6e15
