"""§6.3 compression tests: unbiasedness, error feedback, published ratios."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import compression as C


class TestStochasticRounding:
    def test_unbiased_expectation(self):
        """Gupta et al.: rounding must preserve E[w] — the survey's condition
        for reduced-precision training to converge."""
        x = jnp.full((20_000,), 0.1234567, jnp.float32)
        keys = jax.random.split(jax.random.PRNGKey(0), 8)
        means = [float(jnp.mean(C.stochastic_round(x, k).astype(jnp.float32)))
                 for k in keys]
        est = np.mean(means)
        assert abs(est - 0.1234567) < 2e-4   # bf16 ulp ~1e-3 here; mean ≪ ulp

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_rounds_to_neighbors(self, seed):
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (256,))
        r = C.stochastic_round(x, key).astype(jnp.float32)
        down = x.astype(jnp.bfloat16).astype(jnp.float32)
        # result is one of the two bf16 neighbours → within one bf16 ulp
        ulp = jnp.maximum(jnp.abs(x) * 2 ** -7, 1e-30)
        assert bool(jnp.all(jnp.abs(r - x) <= ulp + 1e-12))


class TestQuantizers:
    @pytest.mark.parametrize("name,tol", [("int8", 0.02), ("int4", 0.2),
                                          ("qsgd", 0.02)])
    def test_roundtrip_error_bounded(self, name, tol):
        comp = C.make_compressor(name)
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, (4096,)) * 0.01
        y = comp(x, key)
        rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
        assert rel < tol * 4

    def test_int_quantization_unbiased(self):
        x = jnp.full((50_000,), 0.003217, jnp.float32)
        comp = C.make_compressor("int8")
        keys = jax.random.split(jax.random.PRNGKey(2), 8)
        est = np.mean([float(jnp.mean(comp(x, k))) for k in keys])
        assert abs(est - 0.003217) / 0.003217 < 0.02

    def test_ternary_values(self):
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (1024,))
        y = C.ternarize(x, key)
        s = float(jnp.max(jnp.abs(x)))
        vals = np.unique(np.round(np.asarray(jnp.abs(y) / s), 6))
        assert set(vals).issubset({0.0, 1.0})

    def test_onebit_two_magnitudes(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (1024,))
        y = C.onebit(x)
        assert len(np.unique(np.asarray(jnp.abs(y)))) == 1
        assert bool(jnp.all(jnp.sign(y) == jnp.sign(x)))


class TestSparsification:
    @given(frac=st.sampled_from([0.01, 0.05, 0.2]), seed=st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_topk_keeps_exactly_topk(self, frac, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (2048,))
        y = C.topk_sparsify(x, frac)
        nnz = int(jnp.sum(y != 0))
        k = int(2048 * frac)
        assert k <= nnz <= k + 8      # ties
        # kept entries are the largest-magnitude ones
        kept_min = float(jnp.min(jnp.abs(y[y != 0])))
        dropped_max = float(jnp.max(jnp.abs(jnp.where(y == 0, x, 0))))
        assert kept_min >= dropped_max - 1e-6


class TestErrorFeedback:
    def test_residual_accounts_all_loss(self):
        """compress+residual must be lossless in sum: sent + residual = g."""
        comp = C.make_compressor("topk", frac=0.05)
        g = {"a": jax.random.normal(jax.random.PRNGKey(5), (512,)),
             "b": jax.random.normal(jax.random.PRNGKey(6), (77,))}
        r0 = jax.tree.map(lambda x: jnp.zeros_like(x), g)
        sent, r1 = comp.compress_with_feedback(g, r0)
        for kk in g:
            np.testing.assert_allclose(np.asarray(sent[kk] + r1[kk]),
                                       np.asarray(g[kk]), rtol=1e-6)

    def test_ef_sgd_converges_where_plain_topk_stalls(self):
        """Survey: 'essential to convergence of SGD with lossy quantization is
        local gradient accumulation'. Failure mode (Karimireddy et al. /
        Seide et al.): a coordinate with large zero-mean gradient noise wins
        every top-1 selection, starving all true descent directions — unless
        the unsent residual accumulates."""
        dim = 10
        A = jnp.eye(dim)
        b = jnp.ones((dim,))                      # solution w* = 1

        def grad(w, t):
            g = A @ w - b
            return g.at[0].add(5.0 if t % 2 == 0 else -5.0)  # noisy coord

        w_ef = jnp.zeros((dim,))
        r = jnp.zeros((dim,))
        w_plain = jnp.zeros((dim,))
        for t in range(400):
            g = grad(w_ef, t) + r
            sent = C.topk_sparsify(g, 1.0 / dim)  # top-1
            r = g - sent
            w_ef = w_ef - 0.1 * sent
            w_plain = w_plain - 0.1 * C.topk_sparsify(grad(w_plain, t), 1.0 / dim)
        sol = jnp.linalg.solve(A, b)
        err_ef = float(jnp.linalg.norm(w_ef - sol))
        err_plain = float(jnp.linalg.norm(w_plain - sol))
        assert err_plain > 2.0            # plain starves coords 1..9
        assert err_ef < 0.4 * err_plain   # EF recovers convergence


class TestRatios:
    def test_published_compression_ratio_range(self):
        """Strom 2015 (survey §6.3.2): threshold+quantization achieved
        846–2871×. topk(frac≈1.5%)+int8 lands in that range analytically."""
        comp = C.make_compressor("topk_int8", frac=0.015)
        assert 40 < comp.ratio() < 60
        aggressive = C.make_compressor("topk_int8", frac=0.0005)
        assert 500 < aggressive.ratio() < 3000

    def test_ratio_ordering(self):
        r = {n: C.make_compressor(n).ratio()
             for n in ("stochastic_bf16", "int8", "int4", "ternary", "onebit")}
        assert r["stochastic_bf16"] < r["int8"] < r["int4"] < r["ternary"] < r["onebit"]


class TestDGC:
    def test_momentum_correction_shapes_and_masking(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(7), (256,))}
        v = jax.tree.map(jnp.zeros_like, g)
        r = jax.tree.map(jnp.zeros_like, g)
        sent, v1, r1 = C.dgc_update(g, v, r, frac=0.1)
        nz = np.asarray(sent["w"] != 0)
        # velocity/residual cleared exactly where sent
        assert np.all(np.asarray(v1["w"])[nz] == 0)
        assert np.all(np.asarray(r1["w"])[nz] == 0)
        assert np.any(np.asarray(v1["w"])[~nz] != 0)
