"""Integration: training decreases loss; checkpoint roundtrip; paper-mode
(explicit collectives + compression) matches pjit mode."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import parallelism as par
from repro.data.pipeline import SyntheticLM, copy_task
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim import make_optimizer
from repro.train import checkpoint as ckpt
from repro.train import trainer
from conftest import run_multidev


def tiny():
    return ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                       vocab_size=64, loss_chunk=32, attn_chunk=32, remat=False)


class TestTrainingConverges:
    def test_loss_decreases_synthetic_lm(self):
        cfg = tiny()
        opt = make_optimizer("adam", lr=3e-3)
        state = trainer.init_state(cfg, opt, jax.random.PRNGKey(0))
        plan = par.make_plan("dp", make_host_mesh())
        step = jax.jit(trainer.make_train_step(cfg, opt, plan))
        data = SyntheticLM(cfg.vocab_size, 64, noise=0.05)
        losses = []
        for batch in data.batches(16, 60):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        first, last = np.mean(losses[:5]), np.mean(losses[-5:])
        assert last < first - 0.35, (first, last)
        assert min(losses) == min(losses[-30:])   # still improving late


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        cfg = tiny()
        opt = make_optimizer("adam")
        state = trainer.init_state(cfg, opt, jax.random.PRNGKey(0))
        path = str(tmp_path / "ck.npz")
        ckpt.save(path, state, step=7)
        restored, step = ckpt.restore(path, jax.eval_shape(lambda: state))
        assert step == 7
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_restore_rejects_shape_mismatch(self, tmp_path):
        cfg = tiny()
        opt = make_optimizer("sgd")
        state = trainer.init_state(cfg, opt, jax.random.PRNGKey(0))
        path = str(tmp_path / "ck.npz")
        ckpt.save(path, state)
        import dataclasses
        cfg2 = dataclasses.replace(cfg, d_model=128, head_dim=32)
        bad = jax.eval_shape(lambda: trainer.init_state(
            cfg2, opt, jax.random.PRNGKey(0)))
        with pytest.raises((ValueError, KeyError)):
            ckpt.restore(path, bad)


@pytest.mark.slow
class TestPaperMode:
    def test_explicit_dp_matches_pjit_mode(self):
        """shard_map DP with our ring allreduce reproduces pjit-mode losses."""
        run_multidev("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs.base import ModelConfig
            from repro.core import parallelism as par
            from repro.data.pipeline import SyntheticLM
            from repro.launch.mesh import make_host_mesh
            from repro.optim import make_optimizer
            from repro.train import trainer
            cfg = ModelConfig(name='t', family='dense', num_layers=1,
                              d_model=32, num_heads=2, num_kv_heads=2,
                              head_dim=16, d_ff=64, vocab_size=32,
                              loss_chunk=32, attn_chunk=32, remat=False)
            mesh = make_host_mesh((4,), ('data',))
            opt = make_optimizer('sgd', lr=1e-2)
            data = SyntheticLM(cfg.vocab_size, 32, noise=0.05)
            batches = list(data.batches(8, 5))

            plan = par.make_plan('dp', mesh)
            s1 = trainer.init_state(cfg, opt, jax.random.PRNGKey(0))
            f1 = jax.jit(trainer.make_train_step(cfg, opt, plan))
            l1 = []
            for b in batches:
                s1, m = f1(s1, b)
                l1.append(float(m['loss']))

            s2 = trainer.init_state(cfg, opt, jax.random.PRNGKey(0))
            f2 = jax.jit(trainer.make_paper_train_step(
                cfg, opt, mesh, algorithm='ring'))
            res = {'_': jnp.zeros((1,), jnp.float32)}
            l2 = []
            for b in batches:
                s2, m, res = f2(s2, b, res)
                l2.append(float(m['loss']))
            np.testing.assert_allclose(l1, l2, rtol=2e-2)
            print('PASS')
        """, devices=4)
