"""Data pipeline determinism + serving loop tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLM, copy_task
from repro.models import transformer as T
from repro.serving import serve


class TestData:
    def test_deterministic_batches(self):
        d1 = SyntheticLM(128, 32, seed=3)
        d2 = SyntheticLM(128, 32, seed=3)
        for b1, b2 in zip(d1.batches(4, 3), d2.batches(4, 3)):
            np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_labels_are_next_token(self):
        d = SyntheticLM(128, 32)
        b = next(iter(d.batches(4, 1)))
        assert b["tokens"].shape == b["labels"].shape == (4, 32)

    def test_learnable_structure(self):
        """Most next-tokens follow the deterministic rule (noise=0.1)."""
        d = SyntheticLM(256, 64, noise=0.1)
        b = next(iter(d.batches(8, 1)))
        t, l = b["tokens"], b["labels"]
        pred = (d.a * t[:, 1:] + d.b * t[:, :-1]) % 256
        frac = float(np.mean(pred == l[:, 1:]))
        assert frac > 0.8

    def test_copy_task(self):
        b = copy_task(4, 16, 32)
        np.testing.assert_array_equal(b["tokens"][:, :8], b["tokens"][:, 8:])


class TestServing:
    def cfg(self):
        return ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                           num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                           vocab_size=50, loss_chunk=16, attn_chunk=16,
                           remat=False)

    def test_generate_shapes_and_determinism(self):
        cfg = self.cfg()
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        prompt = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
        out1 = serve.generate(cfg, params, prompt, max_new=6, temperature=0.0)
        out2 = serve.generate(cfg, params, prompt, max_new=6, temperature=0.0)
        assert out1.shape == (2, 6)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
        assert bool(jnp.all(out1 >= 0)) and bool(jnp.all(out1 < 50))

    def test_sample_temperature_zero_is_argmax(self):
        logits = jnp.asarray([[0.1, 3.0, -1.0]])
        tok = serve.sample(logits, jax.random.PRNGKey(0), temperature=0.0)
        assert int(tok[0]) == 1
