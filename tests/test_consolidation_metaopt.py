"""§6.4 consolidation + §6.5 meta-optimization tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consolidation as con
from repro.core import metaopt as mo


def quad(seed=0, dim=12):
    key = jax.random.PRNGKey(seed)
    A = jnp.diag(jax.random.uniform(key, (dim,), minval=0.5, maxval=3.0))
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (dim,))
    sol = jnp.linalg.solve(A, b)

    def loss(w, batch=None):
        noise = 0.0 if batch is None else batch
        return 0.5 * w["w"] @ A @ w["w"] - (b + noise) @ w["w"]

    return loss, {"w": jnp.zeros(dim)}, sol


class TestEnsembles:
    def test_ensemble_reduces_prediction_variance(self):
        """§6.4.1: averaging m independently-noisy members reduces error."""
        key = jax.random.PRNGKey(0)
        true_w = jax.random.normal(key, (8,))
        members = [{"w": true_w + 0.3 * jax.random.normal(jax.random.PRNGKey(i), (8,))}
                   for i in range(8)]
        x = jax.random.normal(jax.random.PRNGKey(99), (16, 8))
        apply_fn = lambda w, x_: x_ @ w["w"]
        single_err = float(jnp.mean((apply_fn(members[0], x) - x @ true_w) ** 2))
        ens_err = float(jnp.mean((con.ensemble_logits(apply_fn, members, x)
                                  - x @ true_w) ** 2))
        assert ens_err < single_err / 2

    def test_distill_loss_zero_when_matched(self):
        lg = jax.random.normal(jax.random.PRNGKey(0), (4, 10))
        assert float(con.distill_loss(lg, lg)) == pytest.approx(
            float(con.distill_loss(lg, lg)))
        assert float(con.distill_loss(lg, lg)) <= float(con.distill_loss(lg, -lg))


class TestEASGD:
    def test_agents_and_center_converge(self):
        loss, w0, sol = quad()
        agents = [jax.tree.map(lambda p: p + 0.5 * i, w0) for i in range(4)]
        center = w0
        gfn = jax.grad(lambda w: loss(w))
        for _ in range(300):
            grads = [gfn(w) for w in agents]
            agents, center = con.easgd_round(agents, center, grads,
                                             lr=0.1, rho=0.05)
        err = float(jnp.linalg.norm(center["w"] - sol))
        assert err < 0.3

    def test_periodic_averaging_converges(self):
        loss, w0, sol = quad()
        batches = jax.random.normal(jax.random.PRNGKey(2), (60, 12)) * 0.05
        final, losses = con.periodic_average_sgd(
            lambda w, b: loss(w, b), w0, batches, agents=3, lr=0.1,
            avg_every=10)
        assert float(jnp.linalg.norm(final["w"] - sol)) < 0.4
        assert losses[-1] < losses[0]


class TestMetaOpt:
    def make_train_eval(self):
        loss, w0, sol = quad()
        gfn = jax.jit(jax.grad(lambda w: loss(w)))

        def train_eval(hypers, steps, state):
            w = state if state is not None else w0
            for _ in range(steps):
                g = gfn(w)
                w = jax.tree.map(lambda p, g_: p - hypers["lr"] * g_, w, g)
            return w, -float(loss(w))       # higher is better

        return train_eval

    def test_grid_search_finds_reasonable_lr(self):
        te = self.make_train_eval()
        best, score, table = mo.grid_search(
            te, {"lr": [1e-4, 1e-2, 0.2, 2.0]}, steps=40)
        assert best["lr"] == 0.2            # 2.0 diverges (λmax·lr > 2)
        assert len(table) == 4

    def test_random_search_runs(self):
        te = self.make_train_eval()
        best, score, table = mo.random_search(
            te, {"lr": (1e-4, 1.0)}, steps=30, trials=8)
        assert len(table) == 8 and best is not None

    def test_pbt_improves_over_rounds_and_beats_worst_seed(self):
        te = self.make_train_eval()
        init = [{"lr": v} for v in (1e-4, 1e-3, 0.05, 0.3)]
        best, hist = mo.population_based_training(
            te, init, population=4, rounds=6, steps_per_round=15)
        first_best = max(s for _, s in hist[0])
        last_best = max(s for _, s in hist[-1])
        assert last_best >= first_best
        # the bad seeds got replaced: final population no longer contains 1e-4
        final_lrs = [h["lr"] for h, _ in hist[-1]]
        assert min(final_lrs) > 1e-4
