"""Loop-aware HLO analyzer tests: known FLOPs, trip counts, collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hlo_analysis as ha
from conftest import run_multidev


def compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestFlopCounting:
    def test_plain_matmul(self):
        a = jnp.zeros((128, 256), jnp.float32)
        b = jnp.zeros((256, 64), jnp.float32)
        txt = compiled_text(lambda x, y: x @ y, a, b)
        res = ha.analyze_hlo_text(txt)
        assert res["flops"] == pytest.approx(2 * 128 * 256 * 64, rel=0.01)

    def test_scan_multiplies_by_trip_count(self):
        """The whole reason this module exists: XLA's cost_analysis counts a
        while body once; ours multiplies by the trip count."""
        a = jnp.zeros((64, 64), jnp.float32)

        def loop(x):
            def body(c, _):
                return c @ a, None
            out, _ = jax.lax.scan(body, x, None, length=10)
            return out

        txt = compiled_text(loop, a)
        res = ha.analyze_hlo_text(txt)
        one = 2 * 64 ** 3
        assert res["flops"] == pytest.approx(10 * one, rel=0.05)

    def test_nested_scan(self):
        a = jnp.zeros((32, 32), jnp.float32)

        def inner(x):
            def body(c, _):
                return c @ a, None
            return jax.lax.scan(body, x, None, length=4)[0]

        def outer(x):
            def body(c, _):
                return inner(c), None
            return jax.lax.scan(body, x, None, length=3)[0]

        txt = compiled_text(outer, a)
        res = ha.analyze_hlo_text(txt)
        assert res["flops"] == pytest.approx(12 * 2 * 32 ** 3, rel=0.05)

    def test_matches_xla_when_no_loops(self):
        a = jnp.zeros((128, 128), jnp.float32)
        low = jax.jit(lambda x: (x @ x) @ x).lower(a)
        comp = low.compile()
        ours = ha.analyze_hlo_text(comp.as_text())["flops"]
        xla = ha.cost_analysis_dict(comp).get("flops", 0)
        assert ours == pytest.approx(xla, rel=0.05)


class TestEndToEndFlops:
    def test_model_grad_step_close_to_6nd(self):
        """Integration: analyzer FLOPs ≈ 6·N·D for a tiny decoder grad."""
        from repro.configs.base import ModelConfig
        from repro.models import transformer as T
        cfg = ModelConfig(name="t", family="dense", num_layers=4, d_model=128,
                          num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256,
                          vocab_size=512, loss_chunk=64, attn_chunk=64,
                          remat=False)
        params = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
        batch = {"tokens": jax.ShapeDtypeStruct((4, 128), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((4, 128), jnp.int32)}
        txt = jax.jit(jax.grad(lambda p, b: T.loss_fn(cfg, p, b))) \
            .lower(params, batch).compile().as_text()
        res = ha.analyze_hlo_text(txt)
        model = 6 * cfg.param_count() * 4 * 128
        assert 0.5 * model < res["flops"] < 2.5 * model


@pytest.mark.slow
class TestCollectiveBytes:
    def test_psum_bytes(self):
        run_multidev("""
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.core.compat import shard_map
            from repro.core import hlo_analysis as ha
            mesh = jax.make_mesh((8,), ('x',))
            x = jnp.zeros((8, 1024), jnp.float32)
            f = shard_map(lambda v: jax.lax.psum(v, 'x'), mesh=mesh,
                          in_specs=P('x'), out_specs=P(), check_vma=False)
            txt = jax.jit(f).lower(x).compile().as_text()
            res = ha.analyze_hlo_text(txt)
            total = res['total_collective_bytes']
            # one all-reduce of (1,1024) f32 per device = 4096 bytes result
            assert 4000 <= total <= 16384, total
            print('PASS')
        """)
