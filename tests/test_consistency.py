"""§6.1 model-consistency tests: the staleness/convergence trade-off."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consistency as cons


def quadratic_problem(n_steps=200, dim=20, seed=0):
    key = jax.random.PRNGKey(seed)
    A = jnp.diag(jax.random.uniform(key, (dim,), minval=0.5, maxval=3.0))
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (dim,))

    def loss(params, batch):
        w = params["w"]
        noise = batch
        return 0.5 * w @ A @ w - (b + noise) @ w

    batches = jax.random.normal(jax.random.PRNGKey(2), (n_steps, dim)) * 0.05
    params0 = {"w": jnp.zeros(dim)}
    opt = jnp.linalg.solve(A, b)
    return loss, params0, batches, opt


class TestStaleSGD:
    def test_synchronous_converges(self):
        loss, p0, batches, opt = quadratic_problem()
        final, losses = cons.simulate_stale_sgd(loss, p0, batches, lr=0.1,
                                                staleness=0)
        assert float(jnp.linalg.norm(final["w"] - opt)) < 0.2

    def test_bounded_staleness_still_converges(self):
        """SSP's claim [Ho et al. 2013]: bounded staleness retains convergence."""
        loss, p0, batches, opt = quadratic_problem()
        final, _ = cons.simulate_stale_sgd(loss, p0, batches, lr=0.05,
                                           staleness=4)
        assert float(jnp.linalg.norm(final["w"] - opt)) < 0.4

    def test_staleness_monotonically_hurts(self):
        """The survey's Fig 28 spectrum: more staleness → worse (or equal)
        final error at fixed lr."""
        loss, p0, batches, opt = quadratic_problem(n_steps=150)
        errs = []
        for s in (0, 2, 8):
            final, _ = cons.simulate_stale_sgd(loss, p0, batches, lr=0.1,
                                               staleness=s)
            errs.append(float(jnp.linalg.norm(final["w"] - opt)))
        assert errs[0] <= errs[1] * 1.05
        assert errs[1] <= errs[2] * 1.05

    def test_excessive_staleness_with_high_lr_diverges(self):
        """The survey's motivation for staleness bounds + lr adaptation
        [Gupta et al. 2016]: stale gradients at aggressive lr oscillate."""
        loss, p0, batches, opt = quadratic_problem(n_steps=150)
        f_sync, _ = cons.simulate_stale_sgd(loss, p0, batches, lr=0.55,
                                            staleness=0)
        f_stale, _ = cons.simulate_stale_sgd(loss, p0, batches, lr=0.55,
                                             staleness=8)
        err_sync = float(jnp.linalg.norm(f_sync["w"] - opt))
        err_stale = float(jnp.linalg.norm(f_stale["w"] - opt))
        assert err_stale > 2 * err_sync or not np.isfinite(err_stale)


class TestAsyncAgents:
    def test_downpour_sim_converges(self):
        loss, p0, batches, opt = quadratic_problem(n_steps=300)
        final, losses = cons.simulate_async_agents(loss, p0, batches, lr=0.05,
                                                   agents=4)
        assert float(jnp.linalg.norm(final["w"] - opt)) < 0.5
