"""Gradient-accumulation equivalence + end-to-end dry-run smoke."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import parallelism as par
from repro.launch.mesh import make_host_mesh
from repro.optim import make_optimizer
from repro.train import trainer
from conftest import run_multidev


def tiny():
    return ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                       vocab_size=64, loss_chunk=32, attn_chunk=32, remat=False)


class TestGradAccumulation:
    def test_accum_matches_full_batch(self):
        """accum_steps=4 must produce the same update as one full batch
        (same mean gradient, modulo f32 accumulation order)."""
        cfg = tiny()
        opt = make_optimizer("sgd", lr=1e-2)
        plan = par.make_plan("dp", make_host_mesh())
        key = jax.random.PRNGKey(0)
        batch = {
            "tokens": jax.random.randint(key, (8, 64), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (8, 64), 0, cfg.vocab_size),
        }
        s0 = trainer.init_state(cfg, opt, key)
        full = jax.jit(trainer.make_train_step(cfg, opt, plan, accum_steps=1))
        acc = jax.jit(trainer.make_train_step(cfg, opt, plan, accum_steps=4))
        s1, m1 = full(s0, batch)
        s2, m2 = acc(s0, batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
        for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                        jax.tree_util.tree_leaves(s2["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=2e-3, rtol=2e-2)


@pytest.mark.slow
class TestDryRunEndToEnd:
    def test_dryrun_lowers_and_compiles_on_production_mesh(self):
        """Deliverable (e) in miniature: one full-config decode combo lowers
        + compiles under 512 placeholder devices inside the test suite."""
        run_multidev("""
            from repro.launch.dryrun import run
            rec = run('rwkv6-7b', 'decode_32k', 'single', 'dp_tp', quiet=True)
            assert rec['status'] == 'ok', rec
            assert rec['chips'] == 256
            assert rec['fits_hbm'] is True
            assert rec['roofline']['memory_s'] > 0
            rec2 = run('phi4-mini-3.8b', 'long_500k', 'single', 'dp_tp',
                       quiet=True)
            assert rec2['status'] == 'skipped'
            print('PASS')
        """, devices=512, timeout=900)
