"""Prefix caching in the paged KV pool: refcounted shared blocks, the prefix
index, LRU eviction — plus a seeded property-test harness for `BlockPool`
and engine-level soak/defrag equality against `serve.generate`.

All CPU. Select with `pytest -m prefix_cache` (subset of `-m serving`).
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serving import serve
from repro.serving.engine import (BlockPool, BlockPoolError, Engine,
                                  EngineConfig, prefix_hashes)

pytestmark = [pytest.mark.serving, pytest.mark.prefix_cache]


# --------------------------------------------------------------- prefix hashes
class TestPrefixHashes:
    def test_full_blocks_only_and_chaining(self):
        t = np.arange(11, dtype=np.int32)
        h = prefix_hashes(t, 4)
        assert len(h) == 2                        # 11 tokens, bs=4 -> 2 full
        assert h == prefix_hashes(t[:8], 4)       # tail doesn't matter
        t2 = t.copy()
        t2[0] = 99                                # first block differs ...
        h2 = prefix_hashes(t2, 4)
        assert h2[0] != h[0] and h2[1] != h[1]    # ... chain diverges entirely
        t3 = t.copy()
        t3[5] = 99                                # second block differs
        h3 = prefix_hashes(t3, 4)
        assert h3[0] == h[0] and h3[1] != h[1]

    def test_deterministic_across_calls(self):
        t = np.asarray([3, 1, 4, 1, 5, 9, 2, 6], np.int32)
        assert prefix_hashes(t, 2) == prefix_hashes(t.copy(), 2)


# ------------------------------------------------------------ pool prefix API
class TestPoolPrefixAPI:
    def _registered_seq(self, pool, rid, tokens):
        hashes = prefix_hashes(tokens, pool.block_size)
        pool.alloc(rid, pool.blocks_for(len(tokens)))
        row = pool.table(rid)
        for i, k in enumerate(hashes):
            pool.register(rid, row[i], k)
        return hashes

    def test_share_refcount_and_release(self):
        pool = BlockPool(8, 4)
        t = np.arange(8, dtype=np.int32)
        hashes = self._registered_seq(pool, "a", t)
        matched = pool.match_prefix(hashes)
        assert matched == pool.table("a")
        pool.share("b", matched)
        assert pool.table("b") == matched
        pool.free_seq("a")
        assert pool.num_free == 6                 # blocks still held by "b"
        pool.free_seq("b")
        assert pool.num_free == 8                 # ref 0 but still cached
        assert pool.num_cached_free == 2
        pool.check()

    def test_cached_free_block_revives_with_content_slot(self):
        pool = BlockPool(8, 4)
        t = np.arange(8, dtype=np.int32)
        hashes = self._registered_seq(pool, "a", t)
        blocks = pool.table("a")
        pool.free_seq("a")
        matched = pool.match_prefix(hashes)
        assert matched == blocks                  # same physical blocks
        pool.share("b", matched)                  # revive off the free list
        assert pool.num_free == 6
        pool.check()

    def test_lru_eviction_under_pressure(self):
        pool = BlockPool(4, 4)
        h1 = self._registered_seq(pool, "a", np.arange(4, dtype=np.int32))
        h2 = self._registered_seq(pool, "b", np.arange(4, 8, dtype=np.int32))
        pool.free_seq("a")                        # "a" freed first -> older
        pool.free_seq("b")
        pool.alloc("c", 3)                        # 2 plain + oldest cached
        assert pool.stats["evictions"] == 1
        assert pool.match_prefix(h1) == []        # "a" evicted (LRU)
        assert len(pool.match_prefix(h2)) == 1    # "b" survived
        pool.check()

    def test_chain_evicts_leaf_first(self):
        """Eviction inside one released chain goes leaf-first: evicting the
        root would orphan every still-cached descendant (match walks the
        chain from the root)."""
        pool = BlockPool(6, 4)
        h = self._registered_seq(pool, "a", np.arange(12, dtype=np.int32))
        pool.free_seq("a")
        pool.alloc("b", 4)                        # 3 plain + 1 eviction
        assert pool.stats["evictions"] == 1
        assert len(pool.match_prefix(h)) == 2     # root survived, leaf gone
        pool.check()

    def test_plain_free_blocks_preferred_over_cached(self):
        pool = BlockPool(6, 4)
        h = self._registered_seq(pool, "a", np.arange(4, dtype=np.int32))
        pool.alloc("b", 2)
        pool.free_seq("a")
        pool.free_seq("b")
        pool.alloc("c", 5)                        # 5 of 6: keep the cached one
        assert pool.stats["evictions"] == 0
        assert len(pool.match_prefix(h)) == 1
        pool.check()

    def test_register_first_writer_wins(self):
        pool = BlockPool(8, 4)
        t = np.arange(4, dtype=np.int32)
        hashes = self._registered_seq(pool, "a", t)
        pool.alloc("b", 1)
        assert not pool.register("b", pool.table("b")[0], hashes[0])
        assert pool.match_prefix(hashes) == pool.table("a")
        pool.check()

    def test_share_errors(self):
        pool = BlockPool(8, 4)
        pool.alloc("a", 2)
        with pytest.raises(BlockPoolError):
            pool.share("b", [7])                  # free and uncached
        with pytest.raises(BlockPoolError):
            pool.share("b", [99])                 # out of range
        blk = pool.table("a")[0]
        with pytest.raises(BlockPoolError):
            pool.share("a", [blk])                # already in own table
        with pytest.raises(BlockPoolError):
            pool.share("b", [blk, blk])           # duplicate in one call
        pool.check()

    def test_double_release_raises(self):
        pool = BlockPool(8, 4)
        self._registered_seq(pool, "a", np.arange(8, dtype=np.int32))
        pool.share("b", pool.table("a"))
        pool.free_seq("b")
        with pytest.raises(BlockPoolError):
            pool.free_seq("b")
        pool.free_seq("a")
        with pytest.raises(BlockPoolError):
            pool.free_seq("a")

    def test_drop_cache_empties_index(self):
        pool = BlockPool(8, 4)
        h = self._registered_seq(pool, "a", np.arange(8, dtype=np.int32))
        pool.free_seq("a")
        assert pool.num_cached_free == 2
        assert pool.drop_cache() == 2
        assert pool.num_cached_free == 0
        assert pool.match_prefix(h) == []
        assert pool.num_free == 8
        pool.check()

    def test_defragment_under_aliasing_rewrites_all_owners(self):
        pool = BlockPool(12, 4)
        t = np.arange(8, dtype=np.int32)
        hashes = self._registered_seq(pool, "a", t)
        pool.alloc("hole", 2)
        pool.share("b", pool.match_prefix(hashes))
        pool.alloc("b", 1)
        pool.free_seq("hole")                     # holes before b's tail
        pre_a, pre_b = pool.table("a"), pool.table("b")
        assert pre_a == pre_b[:2]                 # aliased prefix
        src = pool.defragment()
        assert sorted(src.tolist()) == list(range(12))
        post_a, post_b = pool.table("a"), pool.table("b")
        assert post_a == post_b[:2]               # still aliased, consistently
        for old, new in zip(pre_a + pre_b, post_a + post_b):
            assert src[new] == old                # content follows each block
        # the index followed the shared blocks too
        assert pool.match_prefix(hashes) == post_a
        pool.check()


# ---------------------------------------------------------- property harness
def _consistent_remap(pre_tables, pool, src):
    """After defrag: every owner's table was rewritten by ONE old->new map
    and `src` moves each block's content to its new id."""
    remap = {}
    for rid, pre in pre_tables.items():
        post = pool.table(rid)
        assert len(post) == len(pre)
        for old, new in zip(pre, post):
            assert remap.setdefault(old, new) == new
            assert src[new] == old
    return {rid: pool.table(rid) for rid in pre_tables}


EPISODES = 220


@pytest.mark.parametrize("seed", range(EPISODES))
def test_blockpool_random_episode(seed):
    """Seeded randomized episode: interleaved admit-style share+alloc,
    release, register, defrag, drop_cache and error probes, with the full
    invariant check (`BlockPool.check` + shadow tables) after every step."""
    rng = random.Random(seed)
    bs = rng.choice([2, 4, 8])
    num_blocks = rng.choice([12, 16, 32])
    pool = BlockPool(num_blocks, bs)
    owners = {}                                   # rid -> expected table
    base = [rng.randrange(6) for _ in range(4 * bs)]   # shared-prefix stock
    next_rid = 0

    for _ in range(rng.randint(40, 90)):
        op = rng.random()
        if op < 0.45:                             # admission: share + alloc
            keep = rng.randrange(0, 4 * bs + 1)
            tail = [rng.randrange(6) for _ in range(rng.randint(1, 2 * bs))]
            prompt = np.asarray(base[:keep] + tail, np.int32)
            hashes = prefix_hashes(prompt, bs)
            matched = pool.match_prefix(hashes)
            if matched and len(matched) * bs == len(prompt):
                matched = matched[:-1]            # CoW rule: keep a tail
            need = pool.blocks_for(len(prompt) + rng.randint(1, bs))
            if pool.admit_feasible(matched, need - len(matched)):
                rid = next_rid
                next_rid += 1
                if matched:
                    pool.share(rid, matched)
                fresh = pool.alloc(rid, need - len(matched))
                owners[rid] = list(matched) + fresh
                row = pool.table(rid)
                upto = rng.randint(len(matched), len(hashes))
                for i in range(len(matched), upto):
                    pool.register(rid, row[i], hashes[i])
        elif op < 0.70 and owners:                # release
            rid = rng.choice(sorted(owners))
            pool.free_seq(rid)
            del owners[rid]
            with pytest.raises(BlockPoolError):   # double-release raises
                pool.free_seq(rid)
        elif op < 0.80:                           # defrag
            pre = {r: list(t) for r, t in owners.items()}
            src = pool.defragment()
            assert sorted(src.tolist()) == list(range(num_blocks))
            owners = _consistent_remap(pre, pool, src)
        elif op < 0.88:                           # cache flush
            pool.drop_cache()
            assert pool.num_cached_free == 0
        else:                                     # error probes
            with pytest.raises(BlockPoolError):
                pool.alloc("probe", pool.num_free + 1)
            assert "probe" not in pool._owned
            with pytest.raises(BlockPoolError):
                pool.table("no-such-seq")

        pool.check()
        for rid, expect in owners.items():        # shadow cross-check
            assert pool.table(rid) == expect
        assert (pool.num_free
                == num_blocks - len({b for t in owners.values() for b in t}))

    # drain: release everything, flush the index -> pristine pool
    for rid in sorted(owners):
        pool.free_seq(rid)
    pool.drop_cache()
    pool.check()
    assert pool.num_free == num_blocks
    assert pool.num_cached_free == 0
    assert pool.match_prefix(prefix_hashes(np.asarray(base, np.int32), bs)) == []


# ------------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def cfg():
    return ModelConfig(name="pc-t", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                       vocab_size=50, loss_chunk=16, attn_chunk=16,
                       remat=False, dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(cfg, jax.random.PRNGKey(0))


def _engine(cfg, params, **kw):
    base = dict(block_size=4, num_blocks=64, max_blocks_per_seq=8,
                max_slots=4, prefill_chunk=8)
    base.update(kw)
    return Engine(cfg, params, EngineConfig(**base))


def _ref(cache, cfg, params, prompt, max_new):
    key = (prompt.tobytes(), max_new)
    if key not in cache:
        cache[key] = np.asarray(serve.generate(
            cfg, params, jnp.asarray(prompt)[None], max_new=max_new,
            temperature=0.0))[0]
    return cache[key]


# ------------------------------------------------------------- engine: hits
class TestEnginePrefixCaching:
    def test_replay_hits_and_stays_bit_identical(self, cfg, params):
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, 50, size=13).astype(np.int32)
        eng = _engine(cfg, params)
        r1 = eng.add_request(prompt, 5)
        o1 = eng.drain()
        chunks_first = eng.stats["prefill_chunks"]
        r2 = eng.add_request(prompt, 5)
        o2 = eng.drain()
        np.testing.assert_array_equal(o1[r1], o2[r2])
        assert eng.stats["prefix_hit_tokens"] == 12          # 3 full blocks
        assert eng.stats["prefill_chunks"] == chunks_first + 1   # tail only
        ref = _ref({}, cfg, params, prompt, 5)
        np.testing.assert_array_equal(o2[r2], ref)

    def test_fully_cached_prompt_copy_on_write(self, cfg, params):
        """Prompt length an exact multiple of block_size: the whole prompt is
        cached, so the engine CoW-copies the last block and re-runs only the
        final prompt token for its logits."""
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, 50, size=12).astype(np.int32)   # 3 blocks
        eng = _engine(cfg, params)
        r1 = eng.add_request(prompt, 6)
        o1 = eng.drain()
        r2 = eng.add_request(prompt, 6)
        o2 = eng.drain()
        assert eng.stats["cow_copies"] == 1
        assert eng.stats["prefix_hit_tokens"] == 11          # all but 1 token
        np.testing.assert_array_equal(o1[r1], o2[r2])
        np.testing.assert_array_equal(o2[r2], _ref({}, cfg, params, prompt, 6))
        # shared blocks were never written: a third replay still matches
        r3 = eng.add_request(prompt, 6)
        o3 = eng.drain()
        np.testing.assert_array_equal(o3[r3], o1[r1])

    def test_concurrent_sharers_alias_blocks(self, cfg, params):
        """Staggered arrivals with a common prefix: later requests alias the
        first request's registered blocks while it is still running."""
        rng = np.random.default_rng(2)
        pre = rng.integers(0, 50, size=8).astype(np.int32)
        tails = [rng.integers(0, 50, size=k).astype(np.int32) for k in (3, 5)]
        prompts = [np.concatenate([pre, t]) for t in tails]
        eng = _engine(cfg, params, max_slots=3)
        r0 = eng.add_request(prompts[0], 8)
        eng.step()                                # prefill + register pre
        rids = [eng.add_request(p, 8) for p in prompts[1:]] + [r0]
        eng.step()
        row0 = eng.block_pool.table(r0)
        row1 = eng.block_pool.table(rids[0])
        assert row0[:2] == row1[:2]               # physical aliasing
        assert eng.block_pool._ref[row0[0]] >= 2
        outs = eng.drain()
        refs = {}
        for rid, p in zip([r0] + rids[:-1], prompts):
            np.testing.assert_array_equal(outs[rid], _ref(refs, cfg, params, p, 8))
        assert eng.stats["prefix_hit_tokens"] > 0
        assert eng.block_pool.num_free == eng.ecfg.num_blocks
        eng.block_pool.check()

    def test_defrag_under_aliasing_device_matches_host(self, cfg, params):
        """Mid-flight defragment with live multi-owner blocks: every owner's
        table is rewritten consistently and the device pool gather matches
        the host permutation exactly."""
        rng = np.random.default_rng(3)
        pre = rng.integers(0, 50, size=8).astype(np.int32)
        prompts = [np.concatenate([pre, rng.integers(0, 50, size=k)
                                   .astype(np.int32)]) for k in (2, 4, 6)]
        eng = _engine(cfg, params, num_blocks=32, max_slots=3)
        r0 = eng.add_request(prompts[0], 10)
        eng.step()                                # register the prefix
        r1 = eng.add_request(prompts[1], 10)
        r2 = eng.add_request(prompts[2], 10)
        eng.step()
        tables_pre = {r: eng.block_pool.table(r) for r in (r0, r1, r2)}
        shared = set(tables_pre[r0][:2])
        assert shared == set(tables_pre[r1][:2]) == set(tables_pre[r2][:2])
        before = jax.tree.map(np.asarray, eng.pool_state)
        src = eng.defragment()
        after = jax.tree.map(np.asarray, eng.pool_state)
        for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
            np.testing.assert_array_equal(a, b[:, src])
        _consistent_remap(tables_pre, eng.block_pool, src)
        # shared blocks still shared (moved once)
        post0 = eng.block_pool.table(r0)
        assert post0[:2] == eng.block_pool.table(r1)[:2] \
            == eng.block_pool.table(r2)[:2]
        outs = eng.drain()
        refs = {}
        for rid, p in zip((r0, r1, r2), prompts):
            np.testing.assert_array_equal(outs[rid],
                                          _ref(refs, cfg, params, p, 10))
        eng.block_pool.check()

    def test_soak_equality_with_and_without_caching(self, cfg, params):
        """Randomized arrival traffic (mixed lengths, heavy shared-prefix
        mix, forced evictions via a tiny pool) run to drain: greedy outputs
        are bit-identical to serve.generate per request, with caching on and
        off."""
        rng = np.random.default_rng(7)
        prefixes = [rng.integers(0, 50, size=s).astype(np.int32)
                    for s in (8, 12, 16)]
        reqs = []
        for i in range(12):
            pre = prefixes[int(rng.integers(len(prefixes)))]
            tail = rng.integers(0, 50,
                                size=int(rng.integers(0, 3)) * 4).astype(np.int32)
            prompt = np.concatenate([pre, tail]) if tail.size else pre.copy()
            reqs.append((prompt, int(rng.integers(2, 7))))
        refs = {}
        outs_by_mode = {}
        for caching in (True, False):
            eng = _engine(cfg, params, num_blocks=16, max_slots=3,
                          prefix_caching=caching)
            order = rng.permutation(len(reqs)) if caching else \
                np.asarray(sorted(range(len(reqs))))
            rids = {}
            for i in order:
                prompt, mn = reqs[int(i)]
                rids[int(i)] = eng.add_request(prompt, mn)
                for _ in range(int(rng.integers(0, 3))):
                    eng.step()
            outs = eng.drain()
            for i, (prompt, mn) in enumerate(reqs):
                got = outs[rids[i]]
                np.testing.assert_array_equal(
                    got, _ref(refs, cfg, params, prompt, mn),
                    err_msg=f"caching={caching} request {i}")
            outs_by_mode[caching] = {i: outs[rids[i]] for i in rids}
            assert eng.block_pool.num_free == eng.ecfg.num_blocks
            eng.block_pool.check()
            if caching:
                assert eng.stats["prefix_hit_tokens"] > 0
                assert eng.block_pool.stats["evictions"] > 0   # tiny pool
        for i in outs_by_mode[True]:
            np.testing.assert_array_equal(outs_by_mode[True][i],
                                          outs_by_mode[False][i])

    def test_caching_off_never_registers(self, cfg, params):
        rng = np.random.default_rng(8)
        prompt = rng.integers(0, 50, size=12).astype(np.int32)
        eng = _engine(cfg, params, prefix_caching=False)
        r1 = eng.add_request(prompt, 4)
        eng.drain()
        r2 = eng.add_request(prompt, 4)
        eng.drain()
        assert eng.stats["prefix_hit_tokens"] == 0
        assert eng.block_pool.stats["registrations"] == 0
