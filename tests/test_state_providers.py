"""Per-layer sequence-state providers: ring-buffer paged pool for sliding
windows, O(1) recurrent slabs for rwkv6/mamba2, and the engine serving ALL
families (full / sliding / local_global / ssm / hybrid) bit-identically to
`serve.generate`.

All CPU. Select with `pytest -m state_providers` (subset of `-m serving`).
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention import paged_attention, paged_attention_ref
from repro.models import state_providers as SP
from repro.models import transformer as T
from repro.serving import serve
from repro.serving.engine import BlockPool, Engine, EngineConfig

pytestmark = [pytest.mark.serving, pytest.mark.state_providers]

NEG_INF = -1e30

_COMMON = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
               head_dim=16, d_ff=128, vocab_size=50, loss_chunk=16,
               attn_chunk=16, remat=False, dtype="float32")

FAMILIES = ("full", "sliding", "local_global", "ssm", "hybrid")


def family_cfg(family: str) -> ModelConfig:
    if family == "full":
        return ModelConfig(name="sp-full", family="dense", **_COMMON)
    if family == "sliding":
        return ModelConfig(name="sp-sliding", family="dense",
                           attention_type="sliding", window_size=8, **_COMMON)
    if family == "local_global":
        return ModelConfig(name="sp-lg", family="dense",
                           attention_type="local_global", local_global_ratio=1,
                           window_size=8, **_COMMON)
    if family == "ssm":
        return ModelConfig(name="sp-ssm", family="ssm", ssm_type="rwkv6",
                           ssm_head_dim=32, **_COMMON)
    if family == "hybrid":
        return ModelConfig(name="sp-hybrid", family="hybrid",
                           hybrid_ssm_per_attn=1, ssm_state_dim=8,
                           ssm_head_dim=32, **_COMMON)
    raise ValueError(family)


@pytest.fixture(scope="module")
def fam_params():
    cache = {}

    def get(family):
        if family not in cache:
            cfg = family_cfg(family)
            cache[family] = (cfg, T.init_params(cfg, jax.random.PRNGKey(0)))
        return cache[family]

    return get


def _engine(cfg, params, **kw):
    base = dict(block_size=4, num_blocks=64, max_blocks_per_seq=16,
                max_slots=4, prefill_chunk=8)
    base.update(kw)
    return Engine(cfg, params, EngineConfig(**base))


def _ref_out(cfg, params, prompt, max_new):
    return np.asarray(serve.generate(
        cfg, params, jnp.asarray(prompt)[None], max_new=max_new,
        temperature=0.0))[0]


# ----------------------------------------------------------- provider units
class TestProviderAccounting:
    def test_kinds_per_family(self):
        assert SP.state_kinds(family_cfg("full")) == ["full"]
        assert SP.state_kinds(family_cfg("sliding")) == ["ring"]
        assert SP.state_kinds(family_cfg("local_global")) == ["ring", "full"]
        assert SP.state_kinds(family_cfg("ssm")) == ["rwkv"]
        assert SP.state_kinds(family_cfg("hybrid")) == ["mamba", "full"]

    def test_ring_pages_formula(self):
        assert SP.ring_pages(8, 4) == 3       # 2 intact pages + 1 wrap page
        assert SP.ring_pages(7, 4) == 3
        assert SP.ring_pages(9, 4) == 4
        assert SP.ring_pages(4, 4) == 2

    def test_blocks_needed_per_kind(self):
        def provs(fam):
            return SP.providers_for(family_cfg(fam), num_blocks=64,
                                    block_size=4, max_slots=4)
        # full: O(S) blocks
        assert SP.seq_blocks_needed(provs("full"), 30) == 8
        # ring: capped at ring_pages regardless of length
        assert SP.seq_blocks_needed(provs("sliding"), 30) == 3
        assert SP.seq_blocks_needed(provs("sliding"), 5) == 2
        # recurrent: zero blocks
        assert SP.seq_blocks_needed(provs("ssm"), 10_000) == 0
        # mixed: the full-attention layer dominates (shared block table)
        assert SP.seq_blocks_needed(provs("local_global"), 30) == 8
        assert SP.seq_blocks_needed(provs("hybrid"), 30) == 8

    def test_prefix_caching_soundness_gate(self):
        def provs(fam):
            return SP.providers_for(family_cfg(fam), num_blocks=64,
                                    block_size=4, max_slots=4)
        assert all(p.supports_prefix_caching for p in provs("full"))
        for fam in ("sliding", "local_global", "ssm", "hybrid"):
            assert not all(p.supports_prefix_caching for p in provs(fam))

    def test_state_bytes_per_slot(self):
        provs = SP.providers_for(family_cfg("ssm"), num_blocks=64,
                                 block_size=4, max_slots=4)
        # rwkv6 @ d=64, hd=32: S (2,32,32) f32 + prev/prev_cm (1,64) f32 each
        assert provs[0].state_bytes_per_slot(1000) == 2 * 32 * 32 * 4 + 2 * 64 * 4
        mem = SP.state_memory_per_slot(family_cfg("ssm"), provs, 1000)
        assert mem == 2 * provs[0].state_bytes_per_slot(1000)  # 2 superblocks


# ------------------------------------------------- ring pool property harness
class _RingShadow:
    """Host-side model of ONE ring sequence: absolute positions -> expected
    fingerprints, mapped through the shared BlockPool table modulo the ring."""

    def __init__(self, rid, table, total, window, block_size, ring):
        self.rid, self.table, self.total = rid, list(table), total
        self.window, self.bs, self.ring = window, block_size, ring
        self.pos = 0                     # next position to write

    def slot_of(self, p):
        return self.table[(p // self.bs) % self.ring], p % self.bs

    def fingerprint(self, p):
        return self.rid * 10_000 + p


class TestRingPoolProperties:
    """Seeded episodes over alloc / write / wrap / free / defrag, mirroring
    tests/test_prefix_cache.py's BlockPool harness. A numpy fingerprint
    array stands in for the device pool (defrag applies the SAME
    permutation the engine applies with jnp.take)."""

    N_EPISODES = 60
    STEPS = 120

    def _check_window_readable(self, seq, store):
        """Every position in the window (pos - window, pos) must be intact."""
        lo = max(0, seq.pos - seq.window)
        for p in range(lo, seq.pos):
            blk, off = seq.slot_of(p)
            assert store[blk, off] == seq.fingerprint(p), \
                f"seq {seq.rid} pos {p}: clobbered ring entry"

    def test_seeded_episodes(self):
        for ep in range(self.N_EPISODES):
            self._episode(random.Random(1234 + ep))

    def _episode(self, rng):
        N, bs = 24, 4
        window = rng.choice([5, 8, 12])
        ring = SP.ring_pages(window, bs)
        pool = BlockPool(N, bs)
        store = np.full((N, bs), -1, np.int64)   # stand-in device pool
        live, next_rid = {}, 0

        for _ in range(self.STEPS):
            op = rng.random()
            if op < 0.3 and len(live) < 5:
                total = rng.randrange(1, 60)
                need = min(pool.blocks_for(total), ring)
                if pool.can_alloc(need):
                    rid = next_rid
                    next_rid += 1
                    table = pool.alloc(rid, need)
                    assert len(table) <= ring
                    live[rid] = _RingShadow(rid, table, total, window, bs, ring)
            elif op < 0.75 and live:
                seq = live[rng.choice(sorted(live))]
                for _ in range(rng.randrange(1, 2 * window)):
                    if seq.pos >= seq.total:
                        break
                    blk, off = seq.slot_of(seq.pos)
                    assert blk in pool.table(seq.rid)
                    store[blk, off] = seq.fingerprint(seq.pos)
                    seq.pos += 1
                self._check_window_readable(seq, store)
            elif op < 0.9 and live:
                rid = rng.choice(sorted(live))
                pool.free_seq(rid)
                del live[rid]
            else:
                src = pool.defragment()
                store = store[src]               # new[i] = old[src[i]]
                for seq in live.values():
                    seq.table = pool.table(seq.rid)
            pool.check()
            for seq in live.values():
                self._check_window_readable(seq, store)

        for rid in sorted(live):
            pool.free_seq(rid)
        assert pool.num_free == N


# -------------------------------------------------- ring attention vs oracle
def _build_ring_case(key, B, Hkv, H, hd, bs, window, positions):
    """Simulate the engine's write order: every position 0..pos scattered
    through the ring in sequence (later laps overwrite earlier ones)."""
    R = SP.ring_pages(window, bs)
    N = B * R + 2
    maxp = max(positions) + 1
    k1, k2, k3 = jax.random.split(key, 3)
    k_all = jax.random.normal(k1, (B, maxp, Hkv, hd), jnp.float32)
    v_all = jax.random.normal(k2, (B, maxp, Hkv, hd), jnp.float32)
    q = jax.random.normal(k3, (B, H, hd), jnp.float32)
    kp = np.zeros((N, bs, Hkv, hd), np.float32)
    vp = np.zeros((N, bs, Hkv, hd), np.float32)
    tables = np.zeros((B, R), np.int32)
    for b in range(B):
        tables[b] = 2 + b * R + np.arange(R)
        for p in range(positions[b] + 1):
            blk = tables[b][(p // bs) % R]
            kp[blk, p % bs] = np.asarray(k_all)[b, p]
            vp[blk, p % bs] = np.asarray(v_all)[b, p]
    return q, k_all, v_all, jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(tables)


class TestRingAttention:
    def test_ref_and_kernel_match_dense_window_oracle(self):
        B, Hkv, H, hd, bs, window = 3, 2, 4, 32, 4, 6
        R = SP.ring_pages(window, bs)
        positions = [0, 7, 23]                  # fresh, 2nd page, deep wrap
        q, k_all, v_all, kp, vp, tables = _build_ring_case(
            jax.random.PRNGKey(0), B, Hkv, H, hd, bs, window, positions)
        pos = jnp.asarray(positions, jnp.int32)
        lens = pos + 1
        out_ref = paged_attention_ref(q, kp, vp, tables, lens, window=window,
                                      positions=pos, ring_pages=R)
        out_ker = paged_attention(q, kp, vp, tables, lens, window=window,
                                  positions=pos, ring_pages=R)

        # dense oracle: softmax over exactly the last `window` positions
        g = H // Hkv
        for b in range(B):
            lo = max(0, positions[b] - window + 1)
            ks = jnp.repeat(k_all[b, lo:positions[b] + 1], g, axis=1)
            vs = jnp.repeat(v_all[b, lo:positions[b] + 1], g, axis=1)
            s = jnp.einsum("hd,khd->hk", q[b], ks) * hd ** -0.5
            p = jax.nn.softmax(s, axis=-1)
            want = np.asarray(jnp.einsum("hk,khd->hd", p, vs))
            np.testing.assert_allclose(np.asarray(out_ref[b]), want, atol=2e-5)
            np.testing.assert_allclose(np.asarray(out_ker[b]), want, atol=2e-5)

    def test_inactive_slot_and_stale_lap_masked(self):
        B, Hkv, H, hd, bs, window = 2, 2, 4, 32, 4, 6
        R = SP.ring_pages(window, bs)
        q, k_all, v_all, kp, vp, tables = _build_ring_case(
            jax.random.PRNGKey(1), B, Hkv, H, hd, bs, window, [9, 9])
        pos = jnp.asarray([9, 0], jnp.int32)
        lens = jnp.asarray([10, 0], jnp.int32)  # slot 1 inactive
        # poison every entry outside slot 0's window — including the stale
        # previous-lap offsets of its current page — output must not move
        out1 = paged_attention_ref(q, kp, vp, tables, lens, window=window,
                                   positions=pos, ring_pages=R)
        live = set()
        for p in range(9 - window + 1, 10):
            live.add((int(tables[0][(p // bs) % R]), p % bs))
        kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
        for blk in range(kp2.shape[0]):
            for off in range(bs):
                if (blk, off) not in live:
                    kp2[blk, off] = 1e4
                    vp2[blk, off] = 1e4
        for fn in (paged_attention_ref, paged_attention):
            out2 = fn(q, jnp.asarray(kp2), jnp.asarray(vp2), tables, lens,
                      window=window, positions=pos, ring_pages=R)
            np.testing.assert_allclose(np.asarray(out2[0]),
                                       np.asarray(out1[0]), atol=1e-5)
            assert bool(jnp.all(out2[1] == 0))


# ------------------------------------------------------- engine end-to-end
class TestEngineAllFamilies:
    @pytest.mark.parametrize("family", FAMILIES)
    def test_engine_matches_generate(self, family, fam_params):
        """Acceptance: staggered mixed-length requests through the engine are
        bit-identical to serve.generate for every family. Generation budgets
        exceed the ring capacity so sliding-window paths wrap."""
        cfg, params = fam_params(family)
        rng = np.random.default_rng(7)
        prompts = [rng.integers(0, 50, size=L).astype(np.int32)
                   for L in (3, 11, 6)]
        news = [24, 6, 17]                      # 24 > ring capacity 3*4 = 12
        eng = _engine(cfg, params)
        rids = []
        for p, mn in zip(prompts, news):
            rids.append(eng.add_request(p, mn))
            eng.step()                          # staggered arrivals
        outs = eng.drain()
        for rid, p, mn in zip(rids, prompts, news):
            np.testing.assert_array_equal(outs[rid], _ref_out(cfg, params, p, mn))
        assert eng.block_pool.num_free == eng.ecfg.num_blocks

    def test_sliding_blocks_bounded_under_long_generation(self, fam_params):
        """A sliding-window sequence allocates at most ceil(window/bs)+1
        blocks no matter how long it decodes (acceptance criterion)."""
        cfg, params = fam_params("sliding")
        ring = SP.ring_pages(cfg.window_size, 4)
        eng = _engine(cfg, params, num_blocks=16, max_blocks_per_seq=6)
        rng = np.random.default_rng(3)
        prompts = [rng.integers(0, 50, size=L).astype(np.int32)
                   for L in (3, 11)]
        news = [40, 50]                         # totals 43 / 61 tokens
        rids = [eng.add_request(p, mn) for p, mn in zip(prompts, news)]
        max_blocks = 0
        while eng.scheduler.has_work:
            eng.step()
            for r in eng.scheduler.running.values():
                max_blocks = max(max_blocks, len(eng.block_pool.table(r.rid)))
        assert max_blocks == ring == 3
        for rid, p, mn in zip(rids, prompts, news):
            np.testing.assert_array_equal(
                eng.output(rid), _ref_out(cfg, params, p, mn))

    def test_prefill_chunk_spanning_full_ring_lap(self, fam_params):
        """A prefill chunk LONGER than the ring capacity (C > R*bs = 12) maps
        several chunk positions to the same (block, offset); only the newest
        lap may land — duplicate-index scatter order is undefined. Long
        prompts prefilled through such chunks must still match the oracle."""
        cfg, params = fam_params("sliding")
        rng = np.random.default_rng(23)
        prompts = [rng.integers(0, 50, size=L).astype(np.int32)
                   for L in (29, 17)]
        news = [8, 21]
        eng = _engine(cfg, params, prefill_chunk=16, max_blocks_per_seq=8)
        rids = [eng.add_request(p, mn) for p, mn in zip(prompts, news)]
        outs = eng.drain()
        for rid, p, mn in zip(rids, prompts, news):
            np.testing.assert_array_equal(outs[rid], _ref_out(cfg, params, p, mn))

    def test_sliding_kernel_impl_matches_ref_impl(self, fam_params):
        cfg, params = fam_params("sliding")
        rng = np.random.default_rng(5)
        prompts = [rng.integers(0, 50, size=L).astype(np.int32)
                   for L in (3, 9)]
        news = [18, 7]
        outs = {}
        for impl in ("ref", "kernel"):
            eng = _engine(cfg, params, attn_impl=impl, max_slots=2)
            rids = [eng.add_request(p, mn) for p, mn in zip(prompts, news)]
            res = eng.drain()
            outs[impl] = [res[r] for r in rids]
        for a, b in zip(outs["ref"], outs["kernel"]):
            np.testing.assert_array_equal(a, b)

    def test_hybrid_defrag_mid_flight(self, fam_params):
        """Defrag permutes paged pools and rewrites tables while leaving the
        recurrent slabs alone — outputs stay bit-identical."""
        cfg, params = fam_params("hybrid")
        rng = np.random.default_rng(11)
        prompts = [rng.integers(0, 50, size=L).astype(np.int32)
                   for L in (5, 9, 4)]
        news = [8, 6, 10]
        eng = _engine(cfg, params)
        rids = [eng.add_request(p, mn) for p, mn in zip(prompts, news)]
        for _ in range(3):
            eng.step()
        eng.defragment()
        for _ in range(2):
            eng.step()
        eng.defragment()
        outs = eng.drain()
        for rid, p, mn in zip(rids, prompts, news):
            np.testing.assert_array_equal(outs[rid], _ref_out(cfg, params, p, mn))

    def test_ssm_admits_on_slots_alone(self, fam_params):
        """Recurrent sequences reserve zero blocks: a tiny pool still admits
        max_slots ssm requests at once."""
        cfg, params = fam_params("ssm")
        eng = _engine(cfg, params, num_blocks=1, max_slots=3)
        rng = np.random.default_rng(13)
        prompts = [rng.integers(0, 50, size=6).astype(np.int32)
                   for _ in range(3)]
        rids = [eng.add_request(p, 5) for p in prompts]
        eng.step()
        assert len(eng.scheduler.running) == 3  # all admitted despite 1 block
        outs = eng.drain()
        for rid, p in zip(rids, prompts):
            np.testing.assert_array_equal(outs[rid], _ref_out(cfg, params, p, 5))

    def test_engine_generate_convenience(self, fam_params):
        cfg, params = fam_params("hybrid")
        rng = np.random.default_rng(17)
        prompts = [rng.integers(0, 50, size=L).astype(np.int32)
                   for L in (4, 7)]
        outs = serve.engine_generate(
            cfg, params, prompts, [6, 4],
            engine_cfg=EngineConfig(block_size=4, num_blocks=64,
                                    max_blocks_per_seq=16, max_slots=4,
                                    prefill_chunk=8))
        for out, p, mn in zip(outs, prompts, (6, 4)):
            np.testing.assert_array_equal(out, _ref_out(cfg, params, p, mn))


# ------------------------------------------------------- request validation
class TestAddRequestValidation:
    def test_oversized_total_raises_with_numbers(self, fam_params):
        cfg, params = fam_params("full")
        eng = _engine(cfg, params)              # 16 blocks * 4 = 64 tokens
        with pytest.raises(ValueError, match=r"60.*max_new 10.*70.*18 blocks"):
            eng.add_request(np.zeros(60, np.int32), 10)

    def test_pool_budget_raises_with_numbers(self, fam_params):
        cfg, params = fam_params("full")
        eng = _engine(cfg, params, num_blocks=8, max_blocks_per_seq=32)
        with pytest.raises(ValueError, match=r"pool budget num_blocks 8"):
            eng.add_request(np.zeros(40, np.int32), 10)

    def test_ring_and_ssm_exempt_from_table_width(self, fam_params):
        """Unbounded-context kinds admit totals far beyond the table width."""
        for fam in ("sliding", "ssm"):
            cfg, params = fam_params(fam)
            eng = _engine(cfg, params, max_blocks_per_seq=4)
            rid = eng.add_request(np.zeros(8, np.int32), 60)    # 68 tokens
            outs = eng.drain()
            assert outs[rid].shape == (60,)

    def test_ring_wider_than_table_rejected_at_construction(self, fam_params):
        cfg, params = fam_params("sliding")     # window 8, bs 4 -> ring 3
        with pytest.raises(ValueError, match=r"ring needs 3 blocks"):
            _engine(cfg, params, max_blocks_per_seq=2)
