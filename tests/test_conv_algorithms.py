"""§4.3 convolution algorithms: all four implementations agree; the paper's
numerics claim about Winograd holds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import conv as CV


def data(key, N=2, C=3, H=18, K=4, Ky=3):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (N, C, H, H))
    w = jax.random.normal(k2, (K, C, Ky, Ky)) * 0.2
    return x, w


class TestCrossValidation:
    @pytest.mark.parametrize("algo", ["im2col", "fft", "winograd"])
    def test_matches_direct_3x3(self, algo):
        x, w = data(jax.random.PRNGKey(0))
        ref = CV.conv_direct(x, w)
        out = CV.ALGORITHMS[algo](x, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)

    @pytest.mark.parametrize("algo", ["im2col", "fft"])
    @pytest.mark.parametrize("Ky", [1, 5, 7])
    def test_other_kernel_sizes(self, algo, Ky):
        x, w = data(jax.random.PRNGKey(1), H=20, Ky=Ky)
        ref = CV.conv_direct(x, w)
        out = CV.ALGORITHMS[algo](x, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)

    def test_batch_and_channel_generalization(self):
        x, w = data(jax.random.PRNGKey(2), N=5, C=7, K=11)
        ref = CV.conv_direct(x, w)
        for algo in ("im2col", "fft", "winograd"):
            np.testing.assert_allclose(np.asarray(CV.ALGORITHMS[algo](x, w)),
                                       np.asarray(ref), atol=1e-3, rtol=1e-3)


class TestPaperNumericsClaim:
    def test_winograd_less_accurate_than_im2col(self):
        """§4.3: 'the numerical accuracy of Winograd convolution is generally
        lower than the other methods' — visible at larger magnitudes."""
        x, w = data(jax.random.PRNGKey(3))
        x = x * 100.0
        ref = np.asarray(CV.conv_direct(x.astype(jnp.float64)
                                        if jax.config.jax_enable_x64 else x, w))
        err_wino = np.max(np.abs(np.asarray(CV.conv_winograd(x, w)) - ref))
        err_im2col = np.max(np.abs(np.asarray(CV.conv_im2col(x, w)) - ref))
        assert err_wino >= err_im2col
