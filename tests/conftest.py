"""Shared test utilities. NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; multi-device tests spawn subprocesses via `run_multidev`.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_multidev(code: str, devices: int = 8, timeout: int = 600):
    """Run `code` in a fresh python with N fake devices; returns stdout.
    The code should print 'PASS' on success."""
    env = {**os.environ,
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": os.path.join(REPO, "src")}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr}"
    assert "PASS" in r.stdout, f"no PASS marker:\n{r.stdout}\n{r.stderr}"
    return r.stdout


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)
