"""Expert-parallel MoE fast path: equivalence vs the auto-sharded reference
on a 4-device mesh (subprocess)."""
import pytest

from conftest import run_multidev


@pytest.mark.slow
class TestExpertParallel:
    def test_ep_matches_reference(self):
        run_multidev("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs.base import ModelConfig
            from repro.core import parallelism as par
            from repro.models import moe as M
            mesh = jax.make_mesh((2, 2), ('data', 'model'))
            plan = par.make_plan('dp_tp', mesh)
            cfg = ModelConfig(name='t', family='moe', d_model=32, num_heads=2,
                              num_kv_heads=2, d_ff=64, vocab_size=17,
                              num_experts=4, experts_per_token=2,
                              capacity_factor=8.0)
            assert M.ep_applicable(cfg, plan)
            p = M.init_moe(jax.random.PRNGKey(0), cfg)
            x = (jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
                 ).astype(jnp.bfloat16)
            ref = M.moe_apply(p, x, cfg)               # single-logical-device
            out = jax.jit(lambda p_, x_: M.moe_apply_ep(p_, x_, cfg, plan))(p, x)
            np.testing.assert_allclose(np.asarray(out, np.float32),
                                       np.asarray(ref, np.float32),
                                       atol=0.35, rtol=0.15)
            print('PASS')
        """, devices=4)

    def test_ep_inside_train_step(self):
        """EP path engages through the plan context in a jitted train step."""
        run_multidev("""
            import jax, jax.numpy as jnp
            from repro.configs.base import ModelConfig
            from repro.core import parallelism as par
            from repro.optim import make_optimizer
            from repro.train import trainer
            mesh = jax.make_mesh((2, 2), ('data', 'model'))
            plan = par.make_plan('dp_tp', mesh)
            cfg = ModelConfig(name='t', family='moe', num_layers=2, d_model=32,
                              num_heads=2, num_kv_heads=2, head_dim=16,
                              d_ff=64, vocab_size=64, num_experts=4,
                              experts_per_token=2, loss_chunk=16,
                              attn_chunk=16, remat=True)
            opt = make_optimizer('adam', lr=1e-3)
            state = trainer.init_state(cfg, opt, jax.random.PRNGKey(0))
            batch = {'tokens': jnp.ones((4, 32), jnp.int32),
                     'labels': jnp.ones((4, 32), jnp.int32)}
            step = jax.jit(trainer.make_train_step(cfg, opt, plan))
            new_state, m = step(state, batch)
            loss = float(m['loss'])
            assert 0 < loss < 20 and loss == loss, loss
            print('PASS')
        """, devices=4)
