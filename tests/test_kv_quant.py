"""Quantized paged KV: int8 pools + per-(token, head) f32 scales,
dequantized inside the paged attention kernels (`EngineConfig.kv_quant`).

The load-bearing guarantee mirrors every other engine feature: greedy
outputs through the quantized ENGINE are bit-identical to the quantized
NON-PAGED reference (`serve.generate(kv_quant=...)`) — quantization changes
the numbers (boundedly, vs fp32), but paging, prefix-cache hits, preemption
and chunked prefill must not change them further. Memory acceptance: the
full-attention per-slot state budget drops to <=0.6x fp32 (measured ~0.27x:
2*hkv*(hd+4) vs 2*hkv*hd*4 bytes per token), and the engine's
`kv_quant_bytes_saved_total` gauge reports exactly the pool-layout delta.
All CPU (`pytest -m kv_quant`, subset of `-m serving`)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import state_providers as SP
from repro.models import transformer as T
from repro.serving import serve
from repro.serving.engine import Engine, EngineConfig, KVQuantConfig

pytestmark = [pytest.mark.serving, pytest.mark.kv_quant]

KVQ_FAMILIES = ("full", "sliding", "hybrid")   # ssm holds no KV to quantize


def _model_cfg(family):
    base = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                head_dim=16, d_ff=128, vocab_size=50, loss_chunk=16,
                attn_chunk=16, remat=False, dtype="float32")
    if family == "full":
        return ModelConfig(name="kvq-full", family="dense", **base)
    if family == "sliding":
        return ModelConfig(name="kvq-sliding", family="dense",
                           attention_type="sliding", window_size=4, **base)
    if family == "hybrid":
        return ModelConfig(name="kvq-hybrid", family="hybrid",
                           hybrid_ssm_per_attn=1, ssm_state_dim=8,
                           ssm_head_dim=16, **base)
    raise ValueError(family)


@pytest.fixture(scope="module", params=KVQ_FAMILIES)
def fam_setup(request):
    cfg = _model_cfg(request.param)
    return request.param, cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def _engine(cfg, params, **kw):
    base = dict(block_size=4, num_blocks=64, max_blocks_per_seq=8,
                max_slots=4, prefill_chunk=8, kv_quant=KVQuantConfig())
    base.update(kw)
    return Engine(cfg, params, EngineConfig(**base))


def _ref(cfg, params, prompt, max_new, kv_quant=None):
    return np.asarray(serve.generate(cfg, params, jnp.asarray(prompt)[None],
                                     max_new=max_new, temperature=0.0,
                                     kv_quant=kv_quant))[0]


def _prompts(n, seed=0, lo=3, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 50, size=int(s)).astype(np.int32)
            for s in rng.integers(lo, hi, size=n)]


class TestQuantConfig:
    def test_only_int8_supported(self):
        with pytest.raises(ValueError):
            KVQuantConfig(bits=4)
        assert KVQuantConfig().bits == 8

    def test_hashable_for_step_fn_cache(self):
        assert hash(KVQuantConfig()) == hash(KVQuantConfig(bits=8))


class TestEngineBitIdentity:
    def test_family_bit_identical_to_quantized_serve(self, fam_setup):
        """Acceptance: the quantized engine's greedy outputs equal the
        quantized dense reference across families, with staggered arrivals
        so chunked prefill and decode interleave."""
        family, cfg, params = fam_setup
        kvq = KVQuantConfig()
        eng = _engine(cfg, params)
        prompts, max_new = _prompts(5, seed=2), 10
        rids = []
        for p in prompts:
            rids.append(eng.add_request(p, max_new))
            eng.step()
        outs = eng.drain()
        for rid, p in zip(rids, prompts):
            np.testing.assert_array_equal(
                outs[rid], _ref(cfg, params, p, max_new, kv_quant=kvq),
                err_msg=f"family={family} rid={rid}")
        assert eng.block_pool.num_free == eng.ecfg.num_blocks

    def test_zero_new_decode_variants_past_warmup(self, fam_setup):
        """Quant changes the traced pool pytree, so the AOT warmup must have
        compiled the quantized shapes — a second trace at serving time is a
        recompile regression."""
        family, cfg, params = fam_setup
        eng = _engine(cfg, params)
        for p in _prompts(4, seed=5):
            eng.add_request(p, 8)
        eng.drain()
        v = eng.telemetry.recompiles.variants()
        assert v.get("decode") == 1, f"family={family}: {v}"
        declared = len(eng.prefill_grid)
        assert eng.telemetry.recompiles.unique("prefill") <= declared

    def test_prefix_cache_hits_bit_identical(self):
        """Shared prefix blocks are reused read-only under quant: the scales
        travel with the block (same (N, bs, Hkv) indexing), so a cache hit
        replays EXACTLY the bytes the first request wrote — outputs equal
        the quantized dense reference, which never shares anything."""
        cfg = _model_cfg("full")
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        kvq = KVQuantConfig()
        eng = _engine(cfg, params, prefix_caching=True)
        rng = np.random.default_rng(9)
        prefix = rng.integers(0, 50, size=12).astype(np.int32)
        prime = eng.add_request(prefix, 1)        # populate the prefix index
        eng.drain()
        prompts = [np.concatenate([prefix,
                                   rng.integers(0, 50, size=int(t))
                                   .astype(np.int32)])
                   for t in rng.integers(2, 6, size=3)]
        rids = [eng.add_request(p, 8) for p in prompts]
        outs = eng.drain()
        assert eng.stats["prefix_hit_tokens"] > 0
        for rid, p in zip(rids, prompts):
            np.testing.assert_array_equal(
                outs[rid], _ref(cfg, params, p, 8, kv_quant=kvq),
                err_msg=f"rid={rid}")


class TestMemoryAccounting:
    def test_state_bytes_per_slot_under_0p6x(self):
        """ISSUE acceptance: full-attention per-slot state <=0.6x fp32."""
        cfg = _model_cfg("full")
        for worst in (32, 128):
            byt = {}
            for tag, q in (("fp32", None), ("int8", KVQuantConfig())):
                provs = SP.providers_for(cfg, num_blocks=64, block_size=4,
                                         max_slots=4, max_blocks_per_seq=32,
                                         kv_quant=q)
                byt[tag] = SP.state_memory_per_slot(cfg, provs, worst)
            ratio = byt["int8"] / byt["fp32"]
            assert ratio <= 0.6, f"worst={worst}: {ratio:.3f}"

    def test_bytes_saved_gauge_matches_layout_delta(self, fam_setup):
        family, cfg, params = fam_setup
        eng = _engine(cfg, params)
        saved = eng.telemetry.registry.snapshot()["kv_quant_bytes_saved_total"]
        hkv, hd = cfg.num_kv_heads, cfg.head_dim
        per_tok = 2 * hkv * hd * 4 - 2 * hkv * (hd + 4)
        n_sb, _ = SP.superblock_layout(cfg)
        pooled = sum(1 for p in eng.providers
                     if getattr(p, "kv_quant", None) is not None)
        want = n_sb * pooled * 64 * 4 * per_tok    # num_blocks * block_size
        assert saved == want > 0

    def test_fp32_engine_reports_zero_saved(self):
        cfg = _model_cfg("full")
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        eng = _engine(cfg, params, kv_quant=None)
        assert eng.telemetry.registry.snapshot()[
            "kv_quant_bytes_saved_total"] == 0


class TestBoundedDrift:
    @pytest.mark.parametrize("family", ["full", "sliding"])
    def test_logit_drift_vs_fp32_bounded(self, family):
        """int8 KV is lossy vs fp32 but boundedly so: teacher-forcing the
        fp32 greedy stream through both caches, the worst logit deviation
        stays ~100x below the logit scale (measured ~0.03 on these shapes;
        bound 0.25 vs max-logit ~2.5). Greedy TOKENS may still differ — the
        bit-identity contract is engine-vs-quantized-reference, never
        quant-vs-fp32."""
        cfg = _model_cfg(family)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, 50, size=(2, 12)), jnp.int32)
        cf = T.init_decode_state(cfg, 2, 32)
        cq = T.init_decode_state(cfg, 2, 32, kv_quant=KVQuantConfig())
        lf, cf = T.prefill_step(cfg, params, cf, {"tokens": toks})
        lq, cq = T.prefill_step(cfg, params, cq, {"tokens": toks})
        drift = [float(jnp.max(jnp.abs(lf - lq)))]
        for j in range(8):
            t = jnp.argmax(lf, -1).astype(jnp.int32)
            lf, cf = T.decode_step(cfg, params, cf, {"token": t},
                                   jnp.int32(12 + j))
            lq, cq = T.decode_step(cfg, params, cq, {"token": t},
                                   jnp.int32(12 + j))
            drift.append(float(jnp.max(jnp.abs(lf - lq))))
        assert 0 < max(drift) < 0.25, f"drift={max(drift)}"
