"""Speculative decoding: multi-query verify kernel vs pure-JAX reference
(interpret mode), verify-vs-sequential-decode logits oracle, the greedy
acceptance rule, recurrent rollback via checkpoint selection, drafter units,
and end-to-end engine bit-identity per model family — including under forced
preemption and with zero verify variants compiled past warmup.

The load-bearing guarantee: greedy outputs with ``EngineConfig.spec`` set are
bit-identical to ``serve.generate``; drafting quality only moves the
acceptance rate, never the tokens. All CPU (`pytest -m spec_decode`, subset
of `-m serving`)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention import (paged_attention_ref,
                                           paged_attention_verify,
                                           paged_attention_verify_ref)
from repro.models import state_providers as SP
from repro.models import transformer as T
from repro.serving import serve
from repro.serving.engine import (Drafter, Engine, EngineConfig,
                                  KVQuantConfig, NgramDrafter, OversubConfig,
                                  ReplayDrafter, SpecConfig)
from repro.serving.engine import spec as SPEC
from repro.serving.engine.scheduler import DECODING
from repro.serving.telemetry import derive_timeline, validate_order

pytestmark = [pytest.mark.serving, pytest.mark.spec_decode]

K = 4


# ------------------------------------------------------- kernel vs reference
def _verify_case(seed, B, H, Hkv, hd, N, bs, P, dtype, lens, k=K):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, k, H, hd)), dtype)
    kp = jnp.asarray(rng.standard_normal((N, bs, Hkv, hd)), dtype)
    vp = jnp.asarray(rng.standard_normal((N, bs, Hkv, hd)), dtype)
    perm = rng.permutation(N)[:B * P].reshape(B, P)
    return q, kp, vp, jnp.asarray(perm, jnp.int32), jnp.asarray(lens, jnp.int32)


class TestVerifyKernel:
    # lens INCLUDE the K draft tokens; 0 = inactive; 16 = exact page boundary
    FULL_LENS = (K, 7, 13, 0, 16, 29)

    @pytest.mark.parametrize("H,Hkv,hd", [(4, 4, 32), (4, 2, 64), (8, 1, 32)])
    @pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                           (jnp.bfloat16, 0.08)])
    def test_full_matches_ref(self, H, Hkv, hd, dtype, tol):
        q, kp, vp, tables, lens = _verify_case(
            0, len(self.FULL_LENS), H, Hkv, hd, 64, 4, 8, dtype, self.FULL_LENS)
        out = paged_attention_verify(q, kp, vp, tables, lens, interpret=True)
        ref = paged_attention_verify_ref(q, kp, vp, tables, lens)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=tol)
        np.testing.assert_array_equal(np.asarray(out)[3], 0.0)  # inactive row
        np.testing.assert_array_equal(np.asarray(ref)[3], 0.0)

    @pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                           (jnp.bfloat16, 0.08)])
    def test_ring_matches_ref(self, dtype, tol):
        window, bs = 8, 4
        rp = SP.ring_pages(window, bs, draft=K - 1)
        lens = (K, 9, 17, 0, 40)              # 17/40 wrap the ring modulus
        q, kp, vp, tables, lens = _verify_case(
            1, 5, 4, 2, 32, 32, bs, rp, dtype, lens)
        pos = jnp.maximum(lens - 1, 0)
        out = paged_attention_verify(q, kp, vp, tables, lens, window=window,
                                     positions=pos, ring_pages=rp,
                                     interpret=True)
        ref = paged_attention_verify_ref(q, kp, vp, tables, lens,
                                         window=window, positions=pos,
                                         ring_pages=rp)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=tol)
        np.testing.assert_array_equal(np.asarray(out)[3], 0.0)

    def test_verify_rows_equal_single_query_decode(self):
        """Semantic anchor: verify row j IS a one-token decode at position
        lens - K + j (attending lens - K + 1 + j keys) — per row, the
        multi-query sweep must reproduce the single-query path exactly."""
        q, kp, vp, tables, lens = _verify_case(
            2, len(self.FULL_LENS), 4, 2, 32, 64, 4, 8, jnp.float32,
            self.FULL_LENS)
        ref = paged_attention_verify_ref(q, kp, vp, tables, lens)
        for j in range(K):
            lens_j = jnp.where(lens > 0, lens - K + 1 + j, 0)
            dec = paged_attention_ref(q[:, j], kp, vp, tables, lens_j)
            np.testing.assert_allclose(np.asarray(ref[:, j]), np.asarray(dec),
                                       atol=1e-6, err_msg=f"row {j}")

    def test_verify_rows_equal_single_query_decode_ring(self):
        window, bs = 8, 4
        rp = SP.ring_pages(window, bs, draft=K - 1)
        lens = (K, 9, 17, 0, 40)
        q, kp, vp, tables, lens = _verify_case(
            3, 5, 4, 2, 32, 32, bs, rp, jnp.float32, lens)
        pos = jnp.maximum(lens - 1, 0)
        ref = paged_attention_verify_ref(q, kp, vp, tables, lens,
                                         window=window, positions=pos,
                                         ring_pages=rp)
        for j in range(K):
            lens_j = jnp.where(lens > 0, lens - K + 1 + j, 0)
            dec = paged_attention_ref(q[:, j], kp, vp, tables, lens_j,
                                      window=window,
                                      positions=jnp.maximum(lens_j - 1, 0),
                                      ring_pages=rp)
            np.testing.assert_allclose(np.asarray(ref[:, j]), np.asarray(dec),
                                       atol=1e-6, err_msg=f"ring row {j}")

    def test_garbage_beyond_lens_is_masked(self):
        """Stale-KV canonicality: pool contents past each slot's valid length
        (rejected-draft leftovers, freed blocks) must not leak into the
        output — poisoning them changes nothing."""
        B, bs, P, N = len(self.FULL_LENS), 4, 8, 64
        q, kp, vp, tables, lens = _verify_case(
            4, B, 4, 2, 32, N, bs, P, jnp.float32, self.FULL_LENS)
        clean = paged_attention_verify(q, kp, vp, tables, lens, interpret=True)
        kp2, vp2 = np.array(kp), np.array(vp)
        perm, lens_np = np.asarray(tables), np.asarray(lens)
        referenced = set()
        for b in range(B):
            for t in range(int(lens_np[b])):
                referenced.add((int(perm[b, t // bs]), t % bs))
        for blk in range(N):
            for off in range(bs):
                if (blk, off) not in referenced:
                    kp2[blk, off] = 1e4
                    vp2[blk, off] = 1e4
        dirty = paged_attention_verify(q, jnp.asarray(kp2), jnp.asarray(vp2),
                                       tables, lens, interpret=True)
        np.testing.assert_allclose(np.asarray(dirty), np.asarray(clean),
                                   atol=1e-6)

    def test_ring_pages_draft_slack(self):
        assert SP.ring_pages(8, 4) == 3
        assert SP.ring_pages(8, 4, draft=3) == 4       # ceil(11/4) + 1
        assert SP.ring_pages(4, 4, draft=3) == 3
        for d in range(4):
            assert SP.ring_pages(8, 4, draft=d + 1) >= SP.ring_pages(8, 4, draft=d)


# ------------------------------------------------- verify step + acceptance
@pytest.fixture(scope="module")
def cfg():
    return ModelConfig(name="spec-t", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                       vocab_size=50, loss_chunk=16, attn_chunk=16,
                       remat=False, dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def prefilled(cfg, params):
    """Slot 0 prefilled with a 6-token prompt; returns (pool, tables, base,
    first greedy token)."""
    pool = T.init_paged_state(cfg, 32, 4, max_slots=2)
    tables = jnp.arange(16, dtype=jnp.int32).reshape(2, 8)
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    toks = jnp.zeros((1, 8), jnp.int32).at[0, :6].set(jnp.asarray(prompt))
    lg, pool = T.paged_prefill_step(cfg, params, pool, toks, tables[0], 0, 6, 0)
    return pool, tables, 6, int(jnp.argmax(lg[0]))


def _sequential(cfg, params, prefilled, k=3):
    """k one-token decode steps from the prefilled state: returns the fed
    tokens [t0, g0, .., g_{k-2}] and the per-step logits rows."""
    pool, tables, base, t0 = prefilled
    cur, fed, rows = t0, [t0], []
    for j in range(k):
        lg, pool = T.paged_decode_step(
            cfg, params, pool, {"token": jnp.asarray([cur, 0], jnp.int32)},
            tables, jnp.asarray([base + j, 0], jnp.int32),
            jnp.asarray([base + j + 1, 0], jnp.int32))
        rows.append(np.asarray(lg[0]))
        cur = int(jnp.argmax(lg[0]))
        if j < k - 1:
            fed.append(cur)
    return fed, np.stack(rows)


class TestVerifyStep:
    def test_logits_match_sequential_decode(self, cfg, params, prefilled):
        """The verify sweep's K logits rows equal K sequential one-token
        decode steps — the equivalence the acceptance rule stands on."""
        pool, tables, base, _ = prefilled
        fed, rows = _sequential(cfg, params, prefilled, k=3)
        tokens = jnp.zeros((2, 3), jnp.int32).at[0].set(jnp.asarray(fed))
        lg, _ = T.paged_verify_step(cfg, params, pool, tokens, tables,
                                    jnp.asarray([base, 0], jnp.int32),
                                    jnp.asarray([3, 0], jnp.int32))
        np.testing.assert_allclose(np.asarray(lg[0]), rows, rtol=1e-5,
                                   atol=1e-5)
        np.testing.assert_array_equal(np.argmax(np.asarray(lg[0]), -1),
                                      np.argmax(rows, -1))

    @pytest.mark.parametrize("wrong_at,qlim,want", [(None, 3, 3), (2, 3, 2),
                                                    (1, 3, 1), (None, 1, 1)])
    def test_acceptance_rule(self, cfg, params, prefilled, wrong_at, qlim, want):
        """accepts = 1 + longest verified draft prefix, capped at qlims."""
        pool, tables, base, _ = prefilled
        fed, rows = _sequential(cfg, params, prefilled, k=3)
        greedy_true = np.argmax(rows, -1)
        drafts = list(fed)
        if wrong_at is not None:   # corrupt draft at position wrong_at
            drafts[wrong_at] = int(greedy_true[wrong_at - 1] + 1) % cfg.vocab_size
        tokens = jnp.zeros((2, 3), jnp.int32).at[0].set(jnp.asarray(drafts))
        greedy, accepts, _, new_lens, new_pool = SPEC.verify_step(
            cfg, params, pool, tokens, tables,
            jnp.asarray([base, 0], jnp.int32), jnp.asarray([True, False]),
            jnp.asarray([qlim, 0], jnp.int32))
        assert int(accepts[0]) == want and int(accepts[1]) == 0
        assert int(new_lens[0]) == base + want and int(new_lens[1]) == 0
        # emitted tokens (the accepted run) match the sequential greedy
        np.testing.assert_array_equal(np.asarray(greedy[0, :want]),
                                      greedy_true[:want])
        assert set(new_pool) == set(pool)

    def test_all_inactive_round_trips_pool(self, cfg, params, prefilled):
        """The engine's warmup call: every slot inactive, qlims 0 — the pool
        must come back bit-identical (this is what makes warmup free)."""
        pool, tables, _, _ = prefilled
        z = jnp.zeros((2,), jnp.int32)
        _, accepts, _, new_lens, new_pool = SPEC.verify_step(
            cfg, params, pool, jnp.zeros((2, 3), jnp.int32), tables, z,
            jnp.zeros((2,), bool), z)
        assert np.asarray(accepts).tolist() == [0, 0]
        assert np.asarray(new_lens).tolist() == [0, 0]
        for a, b in zip(jax.tree.leaves(pool), jax.tree.leaves(new_pool)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_select_checkpoint_picks_accepted_and_keeps_old(self):
        cps = jnp.arange(1 * 3 * 2 * 4, dtype=jnp.float32).reshape(1, 3, 2, 4)
        old = -jnp.ones((1, 2, 4), jnp.float32)
        out = SP.select_checkpoint(cps, jnp.asarray([2, 0], jnp.int32), old)
        np.testing.assert_array_equal(np.asarray(out[0, 0]),
                                      np.asarray(cps[0, 1, 0]))
        np.testing.assert_array_equal(np.asarray(out[0, 1]),
                                      np.asarray(old[0, 1]))


# ------------------------------------------------------------------ drafters
class _ConstantDrafter:
    """Deliberately terrible drafter (protocol via duck typing): wrong
    guesses must only cost acceptance, never correctness."""

    def __init__(self, tok=0):
        self.tok = tok

    def propose(self, rid, context, n):
        return np.full((n,), self.tok, np.int32)

    def forget(self, rid):
        pass


class TestDrafters:
    def test_ngram_proposes_seen_continuation(self):
        d = NgramDrafter(3)
        out = d.propose(1, np.asarray([1, 2, 3, 4, 1, 2, 3]), 2)
        np.testing.assert_array_equal(out, [4, 1])
        # accepted run extends the stream; the cursor keeps streaming
        out = d.propose(1, np.asarray([1, 2, 3, 4, 1, 2, 3, 4, 1]), 2)
        np.testing.assert_array_equal(out, [2, 3])

    def test_ngram_fallback_repeats_last_token(self):
        d = NgramDrafter(3)
        np.testing.assert_array_equal(d.propose(1, np.asarray([7]), 3),
                                      [7, 7, 7])

    def test_ngram_forget_then_repropose(self):
        d = NgramDrafter(2)
        ctx = np.asarray([5, 6, 5, 6, 5, 6])
        first = d.propose(9, ctx, 2)
        d.forget(9)
        np.testing.assert_array_equal(d.propose(9, ctx, 2), first)

    def test_replay_drafter_streams_the_remembered_future(self):
        d = ReplayDrafter()
        stream = np.arange(1, 11, dtype=np.int32)
        d.remember(3, stream)
        np.testing.assert_array_equal(d.propose(3, stream[:4], 3), [5, 6, 7])
        np.testing.assert_array_equal(d.propose(3, stream[:9], 3), [10, 9, 9])
        d.forget(3)                      # no-op: streams survive preemption
        np.testing.assert_array_equal(d.propose(3, stream[:4], 3), [5, 6, 7])
        np.testing.assert_array_equal(d.propose(4, stream[:4], 2), [4, 4])

    def test_protocol_duck_typing(self):
        assert isinstance(NgramDrafter(), Drafter)
        assert isinstance(ReplayDrafter(), Drafter)
        assert isinstance(_ConstantDrafter(), Drafter)
        assert not isinstance(object(), Drafter)

    def test_spec_config_validation(self):
        for bad in (1, 33, 0):
            with pytest.raises(ValueError):
                SpecConfig(k=bad)
        with pytest.raises(ValueError):
            SpecConfig(drafter="beam")
        with pytest.raises(TypeError):
            SpecConfig(drafter=42)
        with pytest.raises(ValueError):
            SpecConfig(ngram=0)
        assert isinstance(SpecConfig().build_drafter(), NgramDrafter)
        inst = _ConstantDrafter()
        assert SpecConfig(drafter=inst).build_drafter() is inst


# ------------------------------------------------------------ engine, e2e
def _model_cfg(family):
    base = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                head_dim=16, d_ff=128, vocab_size=50, loss_chunk=16,
                attn_chunk=16, remat=False, dtype="float32")
    if family == "full":
        return ModelConfig(name="sd-full", family="dense", **base)
    if family == "sliding":
        return ModelConfig(name="sd-sliding", family="dense",
                           attention_type="sliding", window_size=4, **base)
    if family == "ssm":
        return ModelConfig(name="sd-ssm", family="ssm", ssm_type="rwkv6",
                           ssm_head_dim=16, **base)
    if family == "hybrid":
        return ModelConfig(name="sd-hybrid", family="hybrid",
                           hybrid_ssm_per_attn=1, ssm_state_dim=8,
                           ssm_head_dim=16, **base)
    raise ValueError(family)


@pytest.fixture(scope="module", params=["full", "sliding", "ssm", "hybrid"])
def fam_setup(request):
    cfg = _model_cfg(request.param)
    return request.param, cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def _engine(cfg, params, **kw):
    base = dict(block_size=4, num_blocks=64, max_blocks_per_seq=8,
                max_slots=4, prefill_chunk=8, spec=SpecConfig(k=K))
    base.update(kw)
    return Engine(cfg, params, EngineConfig(**base))


def _ref(cfg, params, prompt, max_new, kv_quant=None):
    return np.asarray(serve.generate(cfg, params, jnp.asarray(prompt)[None],
                                     max_new=max_new, temperature=0.0,
                                     kv_quant=kv_quant))[0]


def _prompts(n, seed=0, lo=3, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 50, size=int(s)).astype(np.int32)
            for s in rng.integers(lo, hi, size=n)]


class TestSpecEngine:
    def test_family_bit_identical_to_serve(self, fam_setup):
        """Acceptance: greedy outputs with speculation on are bit-identical
        to serve.generate across every state-provider family (sliding runs
        window=4, so the draft-enlarged ring wraps mid-decode)."""
        family, cfg, params = fam_setup
        eng = _engine(cfg, params)
        prompts, max_new = _prompts(5, seed=2), 10
        rids = []
        for p in prompts:
            rids.append(eng.add_request(p, max_new))
            eng.step()                              # staggered arrivals
        outs = eng.drain()
        for rid, p in zip(rids, prompts):
            np.testing.assert_array_equal(
                outs[rid], _ref(cfg, params, p, max_new),
                err_msg=f"family={family} rid={rid}")
        assert eng.block_pool.num_free == eng.ecfg.num_blocks

    def test_forced_preemption_soak_bit_identical(self, fam_setup):
        """Every request is evicted at a distinct decode depth while
        speculation runs; resume re-prefills over canonical KV (positions
        beyond seq_lens are rejected-draft leftovers the causal bound masks)
        and the drained outputs still match serve.generate bit-for-bit."""
        family, cfg, params = fam_setup
        eng = _engine(cfg, params, oversub=OversubConfig())
        prompts, max_new = _prompts(4, seed=1), 10
        rids = [eng.add_request(p, max_new) for p in prompts]
        pending, steps = list(rids), 0
        while pending and steps < 200:
            eng.step()
            steps += 1
            for rid in list(pending):
                req = eng.requests[rid]
                if req.state == DECODING and len(req.out_tokens) >= rids.index(rid) + 1:
                    assert eng.preempt_request(rid)
                    pending.remove(rid)
        assert not pending, "not every request reached its eviction point"
        outs = eng.drain()
        for rid, p in zip(rids, prompts):
            np.testing.assert_array_equal(
                outs[rid], _ref(cfg, params, p, max_new),
                err_msg=f"family={family} rid={rid}")
        assert eng.stats["preemptions"] >= len(rids)
        assert eng.telemetry.recompiles.variants().get("verify") == 1
        for rid in rids:
            validate_order(eng.telemetry.tracer.request_events(rid))
        assert eng.block_pool.num_free == eng.ecfg.num_blocks
        eng.block_pool.check()

    @pytest.mark.kv_quant
    def test_quantized_kv_spec_soak_bit_identical(self, fam_setup):
        """Speculation over int8 paged KV, with every request force-evicted
        mid-decode: the verify kernel dequantizes in-register, rejected
        drafts roll back by seq_lens alone (their quantized writes beyond the
        bound are masked), and greedy outputs still match the quantized
        dense reference bit-for-bit with zero verify variants past warmup."""
        family, cfg, params = fam_setup
        kvq = KVQuantConfig()
        eng = _engine(cfg, params, oversub=OversubConfig(), kv_quant=kvq)
        prompts, max_new = _prompts(4, seed=7), 10
        rids = [eng.add_request(p, max_new) for p in prompts]
        pending, steps = list(rids), 0
        while pending and steps < 200:
            eng.step()
            steps += 1
            for rid in list(pending):
                req = eng.requests[rid]
                if (req.state == DECODING
                        and len(req.out_tokens) >= rids.index(rid) + 1):
                    assert eng.preempt_request(rid)
                    pending.remove(rid)
        assert not pending, "not every request reached its eviction point"
        outs = eng.drain()
        for rid, p in zip(rids, prompts):
            np.testing.assert_array_equal(
                outs[rid], _ref(cfg, params, p, max_new, kv_quant=kvq),
                err_msg=f"family={family} rid={rid}")
        assert eng.stats["preemptions"] >= len(rids)
        assert eng.telemetry.recompiles.variants().get("verify") == 1
        assert eng.block_pool.num_free == eng.ecfg.num_blocks
        eng.block_pool.check()

    @pytest.mark.parametrize("family", ["full", "sliding"])
    def test_kernel_impl_bit_identical(self, family):
        """The Pallas verify kernel (interpret mode off-TPU) drives the same
        greedy streams as the reference attention."""
        cfg = _model_cfg(family)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        eng = _engine(cfg, params, attn_impl="kernel", max_slots=2)
        prompts, max_new = _prompts(2, seed=3), 8
        rids = [eng.add_request(p, max_new) for p in prompts]
        outs = eng.drain()
        for rid, p in zip(rids, prompts):
            np.testing.assert_array_equal(outs[rid],
                                          _ref(cfg, params, p, max_new),
                                          err_msg=f"family={family}")

    def test_wrong_drafts_only_cost_acceptance(self, cfg, params):
        """An adversarially bad drafter (constant token) still yields
        bit-identical output — acceptance degrades to ~1 token/step."""
        eng = _engine(cfg, params, spec=SpecConfig(k=K, drafter=_ConstantDrafter(0)))
        prompts, max_new = _prompts(3, seed=4), 8
        rids = [eng.add_request(p, max_new) for p in prompts]
        outs = eng.drain()
        for rid, p in zip(rids, prompts):
            np.testing.assert_array_equal(outs[rid],
                                          _ref(cfg, params, p, max_new))
        reg = eng.telemetry.registry
        drafted = reg.get("engine_draft_tokens_total").value
        accepted = reg.get("engine_accepted_tokens_total").value
        assert drafted > 0 and 0 <= accepted <= drafted

    def test_replay_drafter_reaches_full_acceptance(self, cfg, params):
        """ReplayDrafter fed the true continuation is the acceptance=1
        ceiling: every non-final verify step advances by min(k, budget)."""
        prompt, max_new = _prompts(1, seed=6, lo=6, hi=7)[0], 9
        ref = _ref(cfg, params, prompt, max_new)
        d = ReplayDrafter()
        eng = _engine(cfg, params, spec=SpecConfig(k=K, drafter=d))
        rid = eng.add_request(prompt, max_new)
        d.remember(rid, np.concatenate([prompt, ref]))   # prompt ++ output
        outs = eng.drain()
        np.testing.assert_array_equal(outs[rid], ref)
        reg = eng.telemetry.registry
        assert (reg.get("engine_accepted_tokens_total").value
                == reg.get("engine_draft_tokens_total").value > 0)

    def test_stop_token_truncates_identically(self, cfg, params):
        """The device may verify past the stop token; the host truncates the
        accepted run exactly where the non-speculative engine stops."""
        prompt, max_new = _prompts(1, seed=8, lo=5, hi=6)[0], 12
        ref = _ref(cfg, params, prompt, max_new)
        stop = int(ref[3])                 # the 4th generated token
        outs = {}
        for name, spec in (("off", None), ("on", SpecConfig(k=K))):
            eng = _engine(cfg, params, spec=spec)
            rid = eng.add_request(prompt, max_new, stop_token=stop)
            outs[name] = eng.drain()[rid]
        np.testing.assert_array_equal(outs["on"], outs["off"])
        assert int(outs["on"][-1]) == stop
        assert len(outs["on"]) <= max_new

    def test_temperature_requests_run_unspeculated(self, cfg, params):
        """temperature > 0 runs with qlims == 1 (host samples the one
        guaranteed token); the request still completes its full budget."""
        prompt, max_new = _prompts(1, seed=9, lo=5, hi=6)[0], 8
        eng = _engine(cfg, params)
        rid = eng.add_request(prompt, max_new, temperature=0.8,
                              key=jax.random.PRNGKey(3))
        out = np.asarray(eng.drain()[rid])
        assert len(out) == max_new
        assert ((0 <= out) & (out < cfg.vocab_size)).all()

    def test_no_new_verify_variants_at_steady_state(self, cfg, params):
        """The verify shape is AOT-warmed at construction; a mixed staggered
        workload must add ZERO compiled variants of any step function."""
        eng = _engine(cfg, params)
        v0 = dict(eng.telemetry.recompiles.variants())
        assert v0.get("verify") == 1
        prompts, news = _prompts(6, seed=5), [3, 8, 5, 10, 2, 7]
        for p, mn in zip(prompts, news):
            eng.add_request(p, mn)
            eng.step()
        eng.drain()
        assert dict(eng.telemetry.recompiles.variants()) == v0

    def test_telemetry_counts_accepted_tokens_not_steps(self, cfg, params):
        """Satellite (b): verify events carry drafted/accepted, decode_token
        carries the accepted run length, and the derived timeline counts
        TOKENS — len(decode_tokens) equals generated-1 even though the
        engine stepped far fewer times."""
        eng = _engine(cfg, params)
        prompts, max_new = _prompts(3, seed=10), 9
        rids = [eng.add_request(p, max_new) for p in prompts]
        outs = eng.drain()
        reg = eng.telemetry.registry
        assert reg.get("engine_draft_tokens_total").value > 0
        assert reg.get("engine_spec_acceptance_rate").count > 0
        for rid, p in zip(rids, prompts):
            evs = eng.telemetry.tracer.request_events(rid)
            validate_order(evs)
            n_verify = sum(ev.name == "verify" for ev in evs)
            assert n_verify > 0
            gen = len(outs[rid])               # drain returns generated only
            tl = derive_timeline(evs)
            assert len(tl["decode_tokens"]) == gen - 1
            assert tl["accepted_tokens"] == gen - 1 - n_verify
            assert tl["draft_tokens"] >= tl["accepted_tokens"]
