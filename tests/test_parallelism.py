"""ShardingPlan rule tests (run on 1 device with an abstract 16x16 mesh via
AbstractMesh — no devices needed for spec computation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.core import parallelism as par


def _abstract_mesh(shape, axes):
    try:
        return AbstractMesh(shape, axes)
    except TypeError:   # older jax: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(axes, shape)))


def mesh_single():
    return _abstract_mesh((16, 16), ("data", "model"))


def mesh_multi():
    return _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


class Leaf:
    def __init__(self, shape):
        self.shape = shape


class TestPlanAxes:
    def test_dp_uses_all_axes_for_batch(self):
        plan = par.make_plan("dp", mesh_single())
        assert set(plan.batch_axes) == {"data", "model"}
        assert plan.tensor_axes == ()

    def test_dp_tp_hybrid(self):
        plan = par.make_plan("dp_tp", mesh_multi())
        assert plan.batch_axes == ("pod", "data")
        assert plan.tensor_axes == ("model",)

    def test_tp_pure(self):
        plan = par.make_plan("tp", mesh_single())
        assert plan.batch_axes == ()
        assert set(plan.tensor_axes) == {"data", "model"}

    def test_unknown_plan_raises(self):
        with pytest.raises(ValueError):
            par.make_plan("nope", mesh_single())


class TestParamRules:
    def setup_method(self):
        self.plan = par.make_plan("dp_tp", mesh_single())

    def test_embed_vocab_sharded(self):
        spec = self.plan.spec_for_param("embed/table", (262144, 3840))
        assert spec == P(("model",), None)

    def test_attention_heads_sharded(self):
        spec = self.plan.spec_for_param("blocks/l0/attn/wq", (8, 3840, 4096))
        assert spec == P(None, None, ("model",))
        spec = self.plan.spec_for_param("blocks/l0/attn/wo", (8, 4096, 3840))
        assert spec == P(None, ("model",), None)

    def test_indivisible_dim_replicated(self):
        # kv dim 8·80=640 ÷ 16 = 40 OK; but 8 heads*hd=120 not ÷16 → replicate
        spec = self.plan.spec_for_param("blocks/l0/attn/wk", (4, 256, 120))
        assert spec == P(None, None, None)

    def test_moe_expert_dim_sharded_when_divisible(self):
        # qwen3: 128 experts ÷ 16 → expert-parallel
        spec = self.plan.spec_for_param("blocks/l0/moe/w_in", (48, 128, 2048, 768))
        assert spec == P(None, ("model",), None, None)
        # mixtral: 8 experts not ÷ 16 → shard d_ff instead
        spec = self.plan.spec_for_param("blocks/l0/moe/w_in", (32, 8, 4096, 14336))
        assert spec == P(None, None, None, ("model",))

    def test_norms_replicated(self):
        assert self.plan.spec_for_param("blocks/l0/ln1/scale", (4, 3840)) == P()


class TestZeRO1:
    def test_opt_state_gains_data_axis(self):
        plan = par.make_plan("dp_tp_zero1", mesh_single())
        params = {"blocks": {"l0": {"mlp": {"w_in": Leaf((8, 4096, 16384))}}}}
        specs = plan.opt_specs(params)
        s = specs["blocks"]["l0"]["mlp"]["w_in"]
        flat = [a for a in s if a is not None]
        assert ("model",) in s or "model" in str(s)
        assert "data" in str(s)     # the ZeRO upgrade

    def test_baseline_opt_state_matches_params(self):
        plan = par.make_plan("dp_tp", mesh_single())
        params = {"w": Leaf((8, 4096, 16384))}
        assert plan.opt_specs(params) == plan.param_specs(params)


class TestBatchAndCache:
    def test_batch_sharded_over_pod_data(self):
        plan = par.make_plan("dp_tp", mesh_multi())
        spec = plan.spec_for_batch_leaf("tokens", (256, 4096))
        assert spec == P(("pod", "data"), None)

    def test_batch_of_one_replicated(self):
        plan = par.make_plan("dp_tp", mesh_single())
        assert plan.spec_for_batch_leaf("tokens", (1, 524288)) == P(None, None)

    def test_cache_seq_sharded_when_batch_unshardable(self):
        plan = par.make_plan("dp_tp_seq", mesh_single())
        spec = plan.spec_for_cache_leaf("blocks/l0/k", (8, 1, 524288, 8, 256))
        assert spec[2] in ("data", ("data",))

    def test_cache_kv_heads_sharded_when_divisible(self):
        plan = par.make_plan("dp_tp", mesh_single())
        spec = plan.spec_for_cache_leaf("blocks/l0/k", (32, 128, 32768, 32, 80))
        assert spec[1] in ("data", ("data",))
        assert spec[3] in ("model", ("model",))


class TestConstrainContext:
    def test_noop_without_context(self):
        x = jnp.ones((4, 8))
        y = par.constrain(x, ("batch", None))
        assert y is x
