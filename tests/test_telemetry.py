"""Serving telemetry: metrics registry (streaming quantiles vs np.percentile),
request-lifecycle event ordering, recompile tracking (unique trace keys),
step-timeline host/device split, exporters (JSONL replay + Prometheus text),
and the disabled-mode guarantees (no events, bit-identical greedy outputs).
All CPU (`-m telemetry`, subset of `-m serving`)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serving import serve
from repro.serving import telemetry as TM
from repro.serving.engine import Engine, EngineConfig

pytestmark = [pytest.mark.serving, pytest.mark.telemetry]


# ------------------------------------------------------------------ registry
class TestRegistry:
    def test_counter_and_gauge(self):
        reg = TM.MetricsRegistry()
        c = reg.counter("c_total", "help text")
        c.inc()
        c.inc(4)
        assert c.value == 5
        with pytest.raises(TM.TelemetryError):
            c.inc(-1)
        g = reg.gauge("g")
        g.set(7)
        g.add(-3)
        assert g.value == 4
        assert reg.counter("c_total") is c          # get-or-create

    def test_kind_conflict_raises(self):
        reg = TM.MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TM.TelemetryError):
            reg.gauge("x")

    def test_histogram_exact_below_compaction(self):
        """Until the buffer first compacts, quantiles are identical to
        np.percentile (linear interpolation) on the raw data."""
        rng = np.random.default_rng(0)
        data = rng.lognormal(mean=0.0, sigma=1.5, size=1000)
        h = TM.Histogram("h", cap=4096)
        for x in data:
            h.observe(x)
        for q in (0, 1, 10, 50, 90, 99, 100):
            np.testing.assert_allclose(h.quantile(q), np.percentile(data, q),
                                       rtol=1e-12)
        assert h.count == 1000
        np.testing.assert_allclose(h.sum, data.sum())
        assert h.min == data.min() and h.max == data.max()

    def test_histogram_streaming_accuracy(self):
        """Past the cap the sketch compacts; rank error must stay within 2%
        of the requested quantile on 20k heavy-tailed samples at cap=256."""
        rng = np.random.default_rng(7)
        data = rng.lognormal(mean=0.0, sigma=2.0, size=20_000)
        h = TM.Histogram("h", cap=256)
        for x in data:
            h.observe(x)
        assert len(h._v) <= 2 * 256                 # memory actually bounded
        for q in (10, 50, 90, 99):
            est = h.quantile(q)
            emp_rank = np.mean(data <= est)
            assert abs(emp_rank - q / 100.0) < 0.02, \
                f"p{q}: est {est} sits at rank {emp_rank}"
        assert h.count == 20_000
        np.testing.assert_allclose(h.sum, data.sum(), rtol=1e-9)
        assert h.min == data.min() and h.max == data.max()

    def test_histogram_edge_cases(self):
        h = TM.Histogram("h")
        assert math.isnan(h.quantile(50))
        h.observe(3.0)
        assert h.quantile(0) == h.quantile(100) == 3.0
        with pytest.raises(TM.TelemetryError):
            h.quantile(101)

    def test_snapshot_and_prometheus_text(self):
        reg = TM.MetricsRegistry()
        reg.counter("reqs_total", "requests").inc(3)
        reg.gauge("depth").set(2)
        h = reg.histogram("lat_seconds", "latency")
        for x in (0.1, 0.2, 0.3):
            h.observe(x)
        snap = reg.snapshot()
        assert snap["reqs_total"] == 3 and snap["depth"] == 2
        assert snap["lat_seconds"]["count"] == 3
        np.testing.assert_allclose(snap["lat_seconds"]["p50"], 0.2)
        text = reg.prometheus_text()
        assert "# HELP reqs_total requests" in text
        assert "# TYPE reqs_total counter" in text
        assert "reqs_total 3" in text
        assert "# TYPE depth gauge" in text
        assert "# TYPE lat_seconds summary" in text
        assert 'lat_seconds{quantile="0.5"} 0.2' in text
        assert "lat_seconds_count 3" in text


# ---------------------------------------------------------- tracer invariants
class TestTracerValidation:
    def test_validate_order_accepts_canonical_stream(self):
        tr = TM.RequestTracer()
        for name in ("arrive", "admit", "prefix_hit", "prefill_chunk",
                     "prefill_chunk", "first_token", "decode_token",
                     "decode_token", "finish"):
            tr.record(0, name)
        TM.validate_order(tr.request_events(0))

    @pytest.mark.parametrize("names,msg", [
        (("admit", "finish"), "arrive"),
        (("arrive", "first_token", "admit"), "order"),
        (("arrive", "admit", "arrive"), "duplicate"),
        (("arrive", "finish", "decode_token"), "finish"),
    ])
    def test_validate_order_rejects(self, names, msg):
        tr = TM.RequestTracer()
        for name in names:
            tr.record(0, name)
        with pytest.raises(TM.TelemetryError, match=msg):
            TM.validate_order(tr.request_events(0))

    def test_timestamp_regression_rejected(self):
        evs = [TM.Event(2.0, 0, "arrive", None),
               TM.Event(1.0, 0, "admit", None)]
        with pytest.raises(TM.TelemetryError, match="regress"):
            TM.validate_order(evs)


# ------------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def cfg():
    return ModelConfig(name="tel-t", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                       vocab_size=50, loss_chunk=16, attn_chunk=16,
                       remat=False, dtype="float32")


@pytest.fixture(scope="module")
def params(cfg):
    return T.init_params(cfg, jax.random.PRNGKey(0))


def _engine(cfg, params, **kw):
    base = dict(block_size=4, num_blocks=64, max_blocks_per_seq=8,
                max_slots=4, prefill_chunk=8)
    base.update(kw)
    return Engine(cfg, params, EngineConfig(**base))


def _requests(n=6, vocab=50, seed=21):
    rng = np.random.default_rng(seed)
    lens = rng.integers(2, 18, size=n)
    news = rng.integers(1, 8, size=n)
    return ([rng.integers(0, vocab, size=int(L)).astype(np.int32)
             for L in lens], [int(m) for m in news])


# ------------------------------------------------------------ engine lifecycle
class TestEngineLifecycle:
    def test_event_ordering_and_derived_metrics(self, cfg, params):
        prompts, news = _requests()
        eng = _engine(cfg, params)
        rids = []
        for p, mn in zip(prompts, news):
            rids.append(eng.add_request(p, mn))
            eng.step()                              # staggered arrivals
        outs = eng.drain()
        for rid, mn in zip(rids, news):
            evs = eng.telemetry.tracer.request_events(rid)
            TM.validate_order(evs)                  # arrive≤admit≤first≤finish
            tl = eng.telemetry.request_timeline(rid)
            assert tl["arrive"] <= tl["admit"] <= tl["first_token"] \
                <= tl["finish"]
            assert tl["queue_wait"] >= 0 and tl["ttft"] >= tl["queue_wait"]
            assert tl["e2e"] >= tl["ttft"]
            # token #1 comes from the final prefill chunk's logits; every
            # later token is a decode step
            assert len(tl["decode_tokens"]) == outs[rid].shape[0] - 1 == mn - 1
            assert all(tl["first_token"] <= t <= tl["finish"]
                       for t in tl["decode_tokens"])
            # prefill chunks all land inside [admit, first_token]
            chunk_ts = [e.t for e in evs if e.name == "prefill_chunk"]
            assert len(chunk_ts) == -(-len(prompts[rids.index(rid)]) // 8)
            assert all(tl["admit"] <= t <= tl["first_token"]
                       for t in chunk_ts)

    def test_lifecycle_histograms_count_requests(self, cfg, params):
        prompts, news = _requests(seed=3)
        eng = _engine(cfg, params)
        for p, mn in zip(prompts, news):
            eng.add_request(p, mn)
        eng.drain()
        reg = eng.telemetry.registry
        for name in ("engine_request_queue_wait_seconds",
                     "engine_request_ttft_seconds",
                     "engine_request_e2e_seconds"):
            h = reg.get(name)
            assert h.count == len(prompts)
            assert h.min >= 0
        assert reg.get("engine_tokens_emitted_total").value == sum(news)

    def test_prefix_hit_and_evict_events(self, cfg, params):
        """A replayed prompt records a prefix_hit event whose token count
        matches the engine counter; cache pressure records evict events."""
        rng = np.random.default_rng(11)
        prompt = rng.integers(0, 50, size=12).astype(np.int32)
        eng = _engine(cfg, params, num_blocks=16)
        eng.add_request(prompt, 2)
        eng.drain()
        r2 = eng.add_request(prompt, 2)             # identical prompt: hit
        eng.drain()
        hits = [e for e in eng.telemetry.tracer.request_events(r2)
                if e.name == "prefix_hit"]
        assert len(hits) == 1
        assert hits[0].data["tokens"] == eng.stats["prefix_hit_tokens"] > 0
        # churn through fresh prompts until the tiny pool must evict
        for i in range(6):
            p = rng.integers(0, 50, size=14).astype(np.int32)
            eng.add_request(p, 2)
            eng.drain()
        evicts = [e for e in eng.telemetry.tracer.events
                  if e.name == "evict"]
        assert len(evicts) == eng.block_pool.stats["evictions"] > 0

    def test_defrag_event_and_counter(self, cfg, params):
        prompts, news = _requests(seed=5)
        eng = _engine(cfg, params)
        for p, mn in zip(prompts[:3], news[:3]):
            eng.add_request(p, mn)
        eng.step()
        eng.step()
        eng.defragment()
        eng.drain()
        assert eng.telemetry.registry.get("engine_defrags_total").value == 1
        assert any(e.name == "defrag" and e.rid is None
                   for e in eng.telemetry.tracer.events)


# ----------------------------------------------------------- recompile tracker
class TestRecompileTracker:
    def test_unit_unique_trace_keys(self):
        tracker = TM.RecompileTracker()
        calls = []
        fn = tracker.wrap("f", lambda *a: calls.append(a))
        fn(jnp.zeros((2, 3)), 1)
        fn(jnp.ones((2, 3)), 2)                     # same shapes: same key
        assert tracker.unique("f") == 1
        fn(jnp.zeros((4, 3)), 1)                    # new shape
        fn(jnp.zeros((2, 3), jnp.int32), 1)         # new dtype
        fn({"a": jnp.zeros((2, 3))})                # new structure
        assert tracker.unique("f") == 4
        assert tracker.total == 4
        assert len(calls) == 5                      # every call goes through

    def test_engine_counts_exactly_one_variant_per_step_fn(self, cfg, params):
        """Fixed-shape decode/prefill must each compile exactly once no
        matter how many requests and steps run."""
        prompts, news = _requests(seed=9)
        eng = _engine(cfg, params)
        for p, mn in zip(prompts, news):
            eng.add_request(p, mn)
        eng.drain()
        v = eng.telemetry.recompiles.variants()
        assert v["decode"] == 1 and v["prefill"] == 1
        assert v["copy_block"] == 0 and v["reset_slot"] == 0
        assert eng.telemetry.recompiles.total == 2
        # replaying a prompt is fully cached -> the copy-on-write block copy
        # dispatches for the first time; a second replay adds nothing
        eng.add_request(prompts[0], 3)
        eng.drain()
        assert eng.telemetry.recompiles.variants()["copy_block"] == 1
        assert eng.telemetry.recompiles.total == 3
        eng.add_request(prompts[0], 3)
        eng.drain()
        assert eng.telemetry.recompiles.total == 3

    def test_hybrid_run_reports_exact_variant_count(self):
        """Acceptance: a hybrid-config run dispatches exactly three compiled
        step variants — decode, prefill, and the recurrent slot reset."""
        hcfg = ModelConfig(name="tel-hy", family="hybrid",
                           hybrid_ssm_per_attn=1, num_layers=2, d_model=64,
                           num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                           vocab_size=50, loss_chunk=16, attn_chunk=16,
                           remat=False, dtype="float32", ssm_state_dim=8,
                           ssm_head_dim=16)
        hparams = T.init_params(hcfg, jax.random.PRNGKey(3))
        prompts, news = _requests(n=4, seed=13)
        eng = _engine(hcfg, hparams)
        for p, mn in zip(prompts, news):
            eng.add_request(p, mn)
        eng.drain()
        assert eng.telemetry.recompiles.variants() == {
            "decode": 1, "prefill": 1, "copy_block": 0, "reset_slot": 1}
        assert eng.telemetry.recompiles.total == 3


# ------------------------------------------------------------- step timeline
class TestStepTimeline:
    def test_step_timing_records_host_device_split(self, cfg, params):
        prompts, news = _requests(seed=15)
        eng = _engine(cfg, params, step_timing=True)
        for p, mn in zip(prompts, news):
            eng.add_request(p, mn)
        steps = 0
        while eng.scheduler.has_work:
            eng.step()
            steps += 1
        assert len(eng.telemetry.steps) == steps > 0
        for entry in eng.telemetry.steps:
            assert entry["host_s"] >= 0 and entry["device_s"] > 0
        reg = eng.telemetry.registry
        assert reg.get("engine_step_host_seconds").count == steps
        assert reg.get("engine_step_device_seconds").count == steps

    def test_throughput_mode_skips_timeline(self, cfg, params):
        prompts, news = _requests(n=2, seed=17)
        eng = _engine(cfg, params)                  # step_timing off
        for p, mn in zip(prompts, news):
            eng.add_request(p, mn)
        eng.drain()
        assert eng.telemetry.steps == []
        assert eng.telemetry.registry.get("engine_step_host_seconds").count == 0


# ----------------------------------------------------- disabled mode + equality
class TestDisabledMode:
    def test_disabled_records_nothing_and_outputs_identical(self, cfg, params):
        """Acceptance: greedy outputs are bit-identical to serve.generate
        with telemetry on, off, and in the blocking timing path."""
        prompts, news = _requests(seed=19)
        outs = {}
        for mode, kw in (("on", {}), ("off", {"telemetry": False}),
                         ("timing", {"step_timing": True})):
            eng = _engine(cfg, params, **kw)
            rids = [eng.add_request(p, mn) for p, mn in zip(prompts, news)]
            res = eng.drain()
            outs[mode] = [res[r] for r in rids]
            if mode == "off":
                assert eng.telemetry.tracer.events == []
                assert eng.telemetry.steps == []
                assert eng.telemetry.recompiles.total == 0
                # back-compat stats stay live with telemetry off
                assert eng.stats["decode_steps"] > 0
                assert eng.stats["emitted"] == sum(news)
        for p, mn, a, b, c in zip(prompts, news, outs["on"], outs["off"],
                                  outs["timing"]):
            ref = np.asarray(serve.generate(
                cfg, params, jnp.asarray(p)[None], max_new=mn,
                temperature=0.0))[0]
            np.testing.assert_array_equal(a, ref)
            np.testing.assert_array_equal(b, ref)
            np.testing.assert_array_equal(c, ref)

    def test_pool_stats_backcompat_standalone(self):
        from repro.serving.engine import BlockPool
        pool = BlockPool(8, 4)
        assert pool.stats == {"lookups": 0, "hit_blocks": 0, "evictions": 0,
                              "registrations": 0}
        pool.note_prefix_lookup(3)
        assert pool.stats["lookups"] == 1 and pool.stats["hit_blocks"] == 3


# ---------------------------------------------------------------- exporters
class TestExporters:
    def test_jsonl_roundtrip_replays_timelines(self, cfg, params, tmp_path):
        """Acceptance: a JSONL trace replays into per-request TTFT/decode
        timelines identical to the live telemetry's."""
        prompts, news = _requests(seed=23)
        eng = _engine(cfg, params)
        rids = []
        for p, mn in zip(prompts, news):
            rids.append(eng.add_request(p, mn))
            eng.step()
        eng.drain()
        path = tmp_path / "trace.jsonl"
        n = eng.telemetry.export_jsonl(path)
        assert n == len(eng.telemetry.tracer.events) > 0
        replay = TM.replay_jsonl(path)
        assert sorted(replay) == sorted(rids)
        for rid in rids:
            live = eng.telemetry.request_timeline(rid)
            got = replay[rid]
            assert got["ttft"] == live["ttft"]
            assert got["queue_wait"] == live["queue_wait"]
            assert got["e2e"] == live["e2e"]
            assert got["decode_tokens"] == live["decode_tokens"]
            assert got["prefix_hit_tokens"] == live["prefix_hit_tokens"]

    def test_engine_prometheus_snapshot_covers_pool_and_engine(self, cfg,
                                                               params):
        prompts, news = _requests(n=3, seed=29)
        eng = _engine(cfg, params)
        for p, mn in zip(prompts, news):
            eng.add_request(p, mn)
        eng.drain()
        text = eng.telemetry.prometheus_text()
        assert f"engine_tokens_emitted_total {sum(news)}" in text
        assert "# TYPE pool_evictions_total counter" in text
        assert "# TYPE engine_request_ttft_seconds summary" in text
        assert f"engine_request_ttft_seconds_count {len(prompts)}" in text
