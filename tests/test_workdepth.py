"""Work-Depth model tests — including the paper's pinned LeNet claim."""
import math

import pytest
hypothesis = pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import workdepth as wd


class TestLeNetPaperClaim:
    def test_total_matches_paper(self):
        """§3.3.1: W = 665,832 and D = 41, exactly."""
        t = wd.lenet5_inference()
        assert t.work == 665_832
        assert t.depth == 41

    def test_per_layer_matches_paper(self):
        ours = wd.lenet5_layers()
        for name, (w, d) in wd.LENET5_PAPER.items():
            if name == "total":
                continue
            assert (ours[name].work, ours[name].depth) == (w, d), name

    def test_average_parallelism_high(self):
        """§3.3.1: 'even the simplest DNN exhibits high levels of
        concurrency' — W/D in the ten-thousands."""
        t = wd.lenet5_inference()
        assert t.avg_parallelism > 10_000


dims = st.integers(min_value=1, max_value=64)


class TestTable4Properties:
    @given(n=dims, cin=dims, cout=dims)
    @settings(max_examples=50, deadline=None)
    def test_fc_work_depth(self, n, cin, cout):
        r = wd.fully_connected(n, cin, cout)
        assert r.work == n * cin * cout
        assert r.depth == (math.ceil(math.log2(cin)) if cin > 1 else 0)

    @given(n=st.integers(1, 4), h=st.integers(8, 32), cin=st.integers(1, 8),
           cout=st.integers(1, 8), k=st.sampled_from([1, 3, 5]))
    @settings(max_examples=50, deadline=None)
    def test_conv_depth_logarithmic(self, n, h, cin, cout, k):
        """Table 4: depth is O(log K + log C_in) — i.e. work/depth is large."""
        r = wd.conv_direct(n, h, h, cin, cout, k, k)
        assert r.depth <= 3 * math.ceil(math.log2(max(k * k * cin, 2)))
        assert r.work >= r.depth  # W dominates D (paper's key point)

    @given(n=dims, c=dims, h=st.integers(2, 32))
    @settings(max_examples=50, deadline=None)
    def test_work_dominates_depth(self, n, c, h):
        """Table 4's punchline: work asymptotically dominates depth for every
        layer type."""
        for r in (wd.activation(n, c, h, h), wd.batchnorm(n, c, h, h),
                  wd.pooling(n, c, h, h, 2, 2)):
            assert r.work >= r.depth


class TestTable6ConvAlgorithms:
    def test_im2col_same_concurrency_as_direct(self):
        """Table 6: Direct and im2col exhibit the same W and D."""
        a = wd.conv_direct(4, 32, 32, 16, 32, 3, 3)
        b = wd.conv_im2col(4, 32, 32, 16, 32, 3, 3)
        assert (a.work, a.depth) == (b.work, b.depth)

    def test_fft_favors_large_kernels(self):
        """§4.3: 'the larger the convolution kernels are, the more beneficial
        FFT becomes' — FFT work is kernel-size independent, direct is not."""
        direct_small = wd.conv_direct(4, 64, 64, 64, 64, 3, 3)
        direct_large = wd.conv_direct(4, 64, 64, 64, 64, 13, 13)
        fft = wd.conv_fft(4, 64, 64, 64, 64)
        assert direct_large.work > direct_small.work
        assert fft.work < direct_large.work           # FFT wins at K=13
        assert fft.work > direct_small.work           # direct wins at K=3

    def test_winograd_small_kernel_work_reduction(self):
        """Winograd reduces multiplications for small kernels (§4.3)."""
        direct = wd.conv_direct(1, 32, 32, 64, 64, 3, 3)
        wino = wd.conv_winograd(1, 32, 32, 64, 64, r=3, m=2)
        assert wino.work < direct.work


class TestTransformerExtension:
    @pytest.mark.parametrize("arch", ["yi-9b", "mixtral-8x7b", "rwkv6-7b"])
    def test_whole_network_wd(self, arch):
        from repro.configs.base import get_config
        cfg = get_config(arch)
        r = wd.transformer_train_wd(cfg, batch=256, seq=4096)
        assert r.work > 1e15           # ~PFLOP-scale step
        assert r.depth < 1e6           # depth stays tiny vs work
        assert r.avg_parallelism > 1e9
