"""Table 3 update-rule tests: each rule vs its closed-form formula."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import OPTIMIZERS, make_optimizer


def quad_setup():
    w0 = {"w": jnp.asarray([1.0, -2.0, 3.0], jnp.float32)}
    g = {"w": jnp.asarray([0.5, -1.0, 2.0], jnp.float32)}
    return w0, g


class TestClosedForm:
    def test_sgd_formula(self):
        """w ← w − η·g"""
        opt = make_optimizer("sgd", lr=0.1)
        w0, g = quad_setup()
        st = opt.init(w0)
        w1, _ = opt.update(g, st, w0)
        np.testing.assert_allclose(np.asarray(w1["w"]),
                                   np.asarray(w0["w"] - 0.1 * g["w"]), rtol=1e-6)

    def test_momentum_formula(self):
        """w^(t+1) = w^(t) + μ(w^(t) − w^(t−1)) − η·g  [Qian 1999]"""
        opt = make_optimizer("momentum", lr=0.1, momentum=0.9)
        w0, g = quad_setup()
        st = opt.init(w0)
        w1, st = opt.update(g, st, w0)       # first step: w_prev = w0
        np.testing.assert_allclose(np.asarray(w1["w"]),
                                   np.asarray(w0["w"] - 0.1 * g["w"]), rtol=1e-6)
        w2, _ = opt.update(g, st, w1)
        expect = w1["w"] + 0.9 * (w1["w"] - w0["w"]) - 0.1 * g["w"]
        np.testing.assert_allclose(np.asarray(w2["w"]), np.asarray(expect), rtol=1e-6)

    def test_adagrad_formula(self):
        """w_i ← w_i − η·g_i / sqrt(Σ g² + ε)  [Duchi et al. 2011]"""
        opt = make_optimizer("adagrad", lr=0.1, eps=1e-8)
        w0, g = quad_setup()
        st = opt.init(w0)
        w1, _ = opt.update(g, st, w0)
        expect = w0["w"] - 0.1 * g["w"] / jnp.sqrt(g["w"] ** 2 + 1e-8)
        np.testing.assert_allclose(np.asarray(w1["w"]), np.asarray(expect), rtol=1e-6)

    def test_rmsprop_formula(self):
        """A' = βA' + (1−β)g²  [Hinton 2012]"""
        opt = make_optimizer("rmsprop", lr=0.1, beta2=0.9, eps=1e-8)
        w0, g = quad_setup()
        st = opt.init(w0)
        w1, _ = opt.update(g, st, w0)
        A = 0.1 * g["w"] ** 2
        expect = w0["w"] - 0.1 * g["w"] / (jnp.sqrt(A) + 1e-8)
        np.testing.assert_allclose(np.asarray(w1["w"]), np.asarray(expect), rtol=1e-6)

    def test_adam_bias_correction(self):
        """First Adam step ≈ −lr·sign(g) (bias-corrected) [Kingma & Ba]."""
        opt = make_optimizer("adam", lr=0.1, eps=1e-12)
        w0, g = quad_setup()
        st = opt.init(w0)
        w1, _ = opt.update(g, st, w0)
        step = np.asarray(w0["w"] - w1["w"])
        np.testing.assert_allclose(step, 0.1 * np.sign(np.asarray(g["w"])), rtol=1e-4)

    def test_gradient_clipping(self):
        opt = make_optimizer("sgd", lr=1.0, grad_clip=1.0)
        w0, g = quad_setup()
        st = opt.init(w0)
        w1, _ = opt.update(g, st, w0)
        norm = float(jnp.linalg.norm(g["w"]))
        np.testing.assert_allclose(np.asarray(w0["w"] - w1["w"]),
                                   np.asarray(g["w"]) / norm, rtol=1e-5)


class TestConvergence:
    @pytest.mark.parametrize("name", OPTIMIZERS)
    def test_all_rules_descend_quadratic(self, name):
        A = jnp.asarray([[3.0, 0.2], [0.2, 1.0]])
        b = jnp.asarray([1.0, -1.0])

        def loss(w):
            return 0.5 * w["w"] @ A @ w["w"] - b @ w["w"]

        opt = make_optimizer(name, lr=0.05)
        w = {"w": jnp.zeros(2)}
        st = opt.init(w)
        l0 = float(loss(w))
        for _ in range(150):
            g = jax.grad(loss)(w)
            w, st = opt.update(g, st, w)
        assert float(loss(w)) < l0 - 0.1

    def test_bf16_params_master_weights(self):
        """Mixed precision: bf16 params, f32 master — updates accumulate."""
        opt = make_optimizer("sgd", lr=1e-3)
        w = {"w": jnp.ones((4,), jnp.bfloat16)}
        st = opt.init(w)
        g = {"w": jnp.full((4,), 1e-4, jnp.float32)}
        for _ in range(50):
            w, st = opt.update(g, st, w)
        # 50 · 1e-7 = 5e-6 — invisible in bf16 alone, tracked in master
        assert float(st["master"]["w"][0]) < 1.0 - 4e-6
