"""SSM equivalence tests: chunked parallel form == per-token recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import ssm as S


def rwkv_cfg():
    return ModelConfig(name="t", family="ssm", ssm_type="rwkv6", d_model=64,
                       num_heads=2, num_kv_heads=2, ssm_head_dim=32, d_ff=128,
                       vocab_size=17)


def mamba_cfg():
    return ModelConfig(name="t", family="hybrid", ssm_type="mamba2", d_model=32,
                       num_heads=2, num_kv_heads=2, ssm_head_dim=16,
                       ssm_state_dim=8, d_ff=64, vocab_size=17)


class TestRWKV6:
    def test_chunked_equals_stepwise(self):
        """Full-sequence chunked WKV == feeding tokens one at a time through
        the stateful decode path."""
        cfg = rwkv_cfg()
        p = S.init_rwkv6(jax.random.PRNGKey(0), cfg)
        B, Sq = 2, 64
        x = (jax.random.normal(jax.random.PRNGKey(1), (B, Sq, 64)) * 0.5
             ).astype(jnp.bfloat16)
        y_full, _ = S.rwkv6_mix(p, x, cfg)

        state = S.init_rwkv6_state(cfg, B)
        st = {"S": state["S"], "prev": state["prev"]}
        ys = []
        for i in range(Sq):
            yi, st = S.rwkv6_mix(p, x[:, i:i + 1], cfg, state=st)
            ys.append(yi)
        y_step = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_step, np.float32),
                                   np.asarray(y_full, np.float32),
                                   atol=0.15, rtol=0.1)

    def test_decay_keeps_state_bounded(self):
        cfg = rwkv_cfg()
        p = S.init_rwkv6(jax.random.PRNGKey(0), cfg)
        x = jnp.ones((1, 256, 64), jnp.bfloat16) * 0.1
        _, st = S.rwkv6_mix(p, x, cfg)
        assert bool(jnp.all(jnp.isfinite(st["S"])))

    def test_state_carry_across_calls(self):
        """mix(x[:32]) then mix(x[32:]) == mix(x) — chunked serving."""
        cfg = rwkv_cfg()
        p = S.init_rwkv6(jax.random.PRNGKey(0), cfg)
        x = (jax.random.normal(jax.random.PRNGKey(2), (1, 64, 64)) * 0.5
             ).astype(jnp.bfloat16)
        y_full, _ = S.rwkv6_mix(p, x, cfg)
        st0 = S.init_rwkv6_state(cfg, 1)
        y1, st = S.rwkv6_mix(p, x[:, :32], cfg,
                             state={"S": st0["S"], "prev": st0["prev"]})
        y2, _ = S.rwkv6_mix(p, x[:, 32:], cfg, state=st)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([y1, y2], 1), np.float32),
            np.asarray(y_full, np.float32), atol=0.15, rtol=0.1)


class TestMamba2:
    def test_chunked_equals_stepwise(self):
        cfg = mamba_cfg()
        p = S.init_mamba2(jax.random.PRNGKey(0), cfg)
        B, Sq = 2, 64
        x = (jax.random.normal(jax.random.PRNGKey(1), (B, Sq, 32)) * 0.5
             ).astype(jnp.bfloat16)
        y_full, _ = S.mamba2_mix(p, x, cfg)

        st = S.init_mamba2_state(cfg, B)
        ys = []
        for i in range(Sq):
            yi, st = S.mamba2_mix(p, x[:, i:i + 1], cfg, state=st)
            ys.append(yi)
        y_step = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_step, np.float32),
                                   np.asarray(y_full, np.float32),
                                   atol=0.15, rtol=0.1)

    def test_causality(self):
        """Perturbing a later token never changes earlier outputs."""
        cfg = mamba_cfg()
        p = S.init_mamba2(jax.random.PRNGKey(0), cfg)
        x = (jax.random.normal(jax.random.PRNGKey(3), (1, 64, 32))
             ).astype(jnp.bfloat16)
        y1, _ = S.mamba2_mix(p, x, cfg)
        x2 = x.at[0, 40].set(50.0)
        y2, _ = S.mamba2_mix(p, x2, cfg)
        np.testing.assert_allclose(np.asarray(y1[0, :40], np.float32),
                                   np.asarray(y2[0, :40], np.float32),
                                   atol=1e-2)
        assert not np.allclose(np.asarray(y1[0, 40:], np.float32),
                               np.asarray(y2[0, 40:], np.float32), atol=1e-2)
