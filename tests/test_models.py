"""Model-layer tests: norms, RoPE, attention semantics, decode==forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, get_config, reduced
from repro.models import attention as A
from repro.models import layers as L
from repro.models import transformer as T


class TestLayers:
    @given(seed=st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_rmsnorm_unit_rms(self, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (4, 64)) * 5
        p = L.init_rmsnorm(64)
        y = L.rmsnorm(p, x)
        rms = jnp.sqrt(jnp.mean(y.astype(jnp.float32) ** 2, axis=-1))
        np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)

    def test_rope_preserves_norm(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 64))
        pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
        y = L.apply_rope(x, pos)
        np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                                   np.asarray(jnp.linalg.norm(x, axis=-1)),
                                   rtol=1e-4)

    def test_rope_relative_position(self):
        """⟨rope(q,i), rope(k,j)⟩ depends only on i−j."""
        q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))
        k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 64))

        def dot_at(i, j):
            qi = L.apply_rope(q, jnp.asarray([[i]]))
            kj = L.apply_rope(k, jnp.asarray([[j]]))
            return float(jnp.sum(qi * kj))

        assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), rel=1e-3)
        assert dot_at(5, 3) != pytest.approx(dot_at(5, 4), rel=1e-3)

    def test_mrope_matches_rope_when_streams_equal(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 4, 64))
        pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
        pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
        np.testing.assert_allclose(
            np.asarray(L.apply_mrope(x, pos3)),
            np.asarray(L.apply_rope(x, pos)), rtol=2e-3, atol=2e-3)


def tiny_cfg(**kw):
    base = dict(name="tiny", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=97, loss_chunk=16, attn_chunk=16, remat=False)
    base.update(kw)
    return ModelConfig(**base)


class TestAttention:
    def test_chunked_equals_naive(self):
        cfg = tiny_cfg(attn_chunk=8)
        p = A.init_attention(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64), jnp.float32) \
            .astype(jnp.bfloat16)
        pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
        out_c = A.attention_train(p, x, pos, cfg, impl="chunked")
        out_n = A.attention_train(p, x, pos, cfg, impl="naive")
        np.testing.assert_allclose(np.asarray(out_c, np.float32),
                                   np.asarray(out_n, np.float32),
                                   atol=0.15, rtol=0.1)

    def test_sliding_window_masks_past(self):
        """Token far past the window cannot influence the output."""
        cfg = tiny_cfg(attention_type="sliding", window_size=8, attn_chunk=16)
        p = A.init_attention(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64)).astype(jnp.bfloat16)
        pos = jnp.arange(64)[None]
        y1 = A.attention_train(p, x, pos, cfg, window=8)
        x2 = x.at[0, 0].set(100.0)          # perturb token 0
        y2 = A.attention_train(p, x2, pos, cfg, window=8)
        # last token (pos 63) is > window away from token 0 → unchanged
        np.testing.assert_allclose(np.asarray(y1[0, -1], np.float32),
                                   np.asarray(y2[0, -1], np.float32), atol=1e-2)
        assert not np.allclose(np.asarray(y1[0, 1], np.float32),
                               np.asarray(y2[0, 1], np.float32), atol=1e-2)


FAMILIES = ["dense", "sliding", "local_global", "moe", "ssm", "hybrid"]


def family_cfg(fam):
    if fam == "dense":
        return tiny_cfg()
    if fam == "sliding":
        return tiny_cfg(attention_type="sliding", window_size=8)
    if fam == "local_global":
        return tiny_cfg(attention_type="local_global", local_global_ratio=1)
    if fam == "moe":
        return tiny_cfg(family="moe", num_experts=4, experts_per_token=2)
    if fam == "ssm":
        return tiny_cfg(family="ssm", ssm_type="rwkv6", num_heads=2,
                        num_kv_heads=2, ssm_head_dim=32, rope_mode="none")
    if fam == "hybrid":
        return tiny_cfg(family="hybrid", ssm_type="mamba2", ssm_state_dim=16,
                        ssm_head_dim=32, hybrid_ssm_per_attn=1)
    raise ValueError(fam)


class TestDecodeMatchesForward:
    """The critical cache-correctness property: token-by-token decode must
    reproduce the teacher-forced forward logits for every family."""

    @pytest.mark.parametrize("fam", FAMILIES)
    def test_decode_equals_forward(self, fam):
        cfg = family_cfg(fam)
        S = 16
        key = jax.random.PRNGKey(0)
        params = T.init_params(cfg, key)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, cfg.vocab_size)
        hidden, _ = T.forward(cfg, params, {"tokens": tokens})
        full_logits = T.logits(cfg, params, hidden)          # (2, S, V)

        cache = T.init_decode_state(cfg, 2, S)
        dec = []
        for i in range(S):
            lg, cache = T.decode_step(cfg, params, cache,
                                      {"token": tokens[:, i]}, jnp.int32(i))
            dec.append(lg)
        dec = jnp.stack(dec, axis=1)                         # (2, S, V)
        np.testing.assert_allclose(np.asarray(dec, np.float32),
                                   np.asarray(full_logits, np.float32),
                                   atol=0.35, rtol=0.12)


class TestGQA:
    def test_kv_equal_heads_is_mha(self):
        cfg_mha = tiny_cfg(num_kv_heads=4)
        p = A.init_attention(jax.random.PRNGKey(0), cfg_mha)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 64)).astype(jnp.bfloat16)
        pos = jnp.arange(16)[None]
        out = A.attention_train(p, x, pos, cfg_mha)
        assert out.shape == (1, 16, 64)
        assert not bool(jnp.any(jnp.isnan(out.astype(jnp.float32))))
