"""Repo lint: serving metrics must flow through the telemetry registry, and
block-pool bookkeeping must flow through the BlockPool API.

Any raw mutation of an ad-hoc stats dict (``self.stats["x"] += 1`` and
friends) inside ``src/repro/serving/`` is a regression back to the three
scattered dicts the registry superseded — only telemetry.py may own metric
state. Likewise any touch of a pool-internal structure (``pool._ref``,
``pool._free`` ...) outside paged_cache.py/oversub.py bypasses the
refcount/prefix-index invariants that preemption's register-then-evict
discipline depends on — callers get alloc/append/share/evict_seq/free_seq,
never the books.

Quantized KV adds a fenced allocator: KV pool/cache leaves ("k", "v" and
their "_scale" companions) may only be materialized by
``state_providers.alloc_kv_pool``, which picks the int8+scales or fp32
layout from the one ``KVQuantConfig``. A raw ``jnp.zeros`` KV dict anywhere
else in models/ or serving/ silently hard-codes the fp32 layout and
desyncs from ``state_bytes_per_slot`` accounting the moment quant is on.

Speculative decoding adds two more fenced stores: per-request draft cursors
(``_draft_state``, owned by the drafters in engine/spec.py) and the verify
scan's recurrent rollback checkpoints (selected only by
``state_providers.select_checkpoint``). Anything else reaching into either
would fork mutable speculation state outside the modules whose invariants
(forget-on-preempt, checkpoint-per-draft-position) keep resume and rollback
exact."""
import pathlib
import re

import pytest

pytestmark = [pytest.mark.serving, pytest.mark.telemetry]

SERVING = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro" / "serving"
MODELS = SERVING.parent / "models"

# .stats[...] followed by an (augmented) assignment; `==` comparisons and
# plain reads don't match because they aren't followed by an assignment op.
_RAW_STATS_MUTATION = re.compile(
    r"\.stats\[[^\]]+\]\s*(?:[-+*/|&^%]|//|>>|<<)?=(?!=)")

# attribute access on BlockPool's private bookkeeping (the refcounts, free
# list, owner tables, and prefix index). `num_free`/`_free_slots` don't
# match: the pattern anchors on the dot before the underscore.
_POOL_INTERNAL = re.compile(
    r"\._(?:free|ref|owned|index|hash_of|n_cached_free)\b")
_POOL_ALLOWED = ("paged_cache.py", "oversub.py")


def test_no_raw_stats_mutations_outside_telemetry():
    assert SERVING.is_dir()
    offenders = []
    for path in sorted(SERVING.rglob("*.py")):
        if path.name == "telemetry.py":
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if _RAW_STATS_MUTATION.search(line):
                offenders.append(f"{path.relative_to(SERVING)}:{lineno}: "
                                 f"{line.strip()}")
    assert not offenders, (
        "raw stats-dict mutations found (route through the telemetry "
        "MetricsRegistry instead):\n" + "\n".join(offenders))


def test_lint_regex_catches_the_banned_patterns():
    bad = ['self.stats["lookups"] += 1',
           "pool.stats['evictions'] = 0",
           'self.stats["x"] //= 2']
    good = ['assert eng.stats["emitted"] == 6',
            'hits = pool.stats["hit_blocks"]',
            'if self.stats["lookups"] == 0:']
    for s in bad:
        assert _RAW_STATS_MUTATION.search(s), s
    for s in good:
        assert not _RAW_STATS_MUTATION.search(s), s


def test_no_pool_internal_access_outside_paged_cache():
    assert SERVING.is_dir()
    offenders = []
    for path in sorted(SERVING.rglob("*.py")):
        if path.name in _POOL_ALLOWED:
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if _POOL_INTERNAL.search(line):
                offenders.append(f"{path.relative_to(SERVING)}:{lineno}: "
                                 f"{line.strip()}")
    assert not offenders, (
        "direct pool-internal access found (use the BlockPool API — "
        "alloc/append/share/register/evict_seq/free_seq):\n"
        + "\n".join(offenders))


# KV pool/cache leaves born outside the quant-aware allocator: a dict
# literal ('"k": jnp.zeros(...)') or dict() kwarg ('k=jnp.zeros(...)', no
# spaces per keyword style) allocating any of the four KV leaf names.
# Spaced local assignments ('k = jnp.zeros(...)') and non-KV leaves
# ('"ln_scale": jnp.ones') don't match.
_KV_POOL_ALLOC = re.compile(
    r"""["'](?:k|v|k_scale|v_scale)["']\s*:\s*jnp\.(?:zeros|ones|empty|full)\b"""
    r"|[(,\s](?:k|v|k_scale|v_scale)=jnp\.(?:zeros|ones|empty|full)\(")
_KV_ALLOC_ALLOWED = ("state_providers.py",)


def test_kv_pool_allocation_only_in_state_providers():
    """Every KV pool/cache must come from state_providers.alloc_kv_pool —
    the single place that knows whether the layout is fp32 or int8+scales
    (EngineConfig.kv_quant)."""
    offenders = []
    for root in (SERVING, MODELS):
        assert root.is_dir()
        for path in sorted(root.rglob("*.py")):
            if path.name in _KV_ALLOC_ALLOWED:
                continue
            for lineno, line in enumerate(path.read_text().splitlines(), 1):
                if _KV_POOL_ALLOC.search(line):
                    offenders.append(f"{path.relative_to(root.parent)}:"
                                     f"{lineno}: {line.strip()}")
    assert not offenders, (
        "raw KV pool/cache allocation found (use "
        "state_providers.alloc_kv_pool, the quant-aware layout owner):\n"
        + "\n".join(offenders))


def test_kv_alloc_lint_regex_catches_the_banned_patterns():
    bad = ['return {"k": jnp.zeros(shape), "v": jnp.zeros(shape)}',
           "cache = dict(k=jnp.zeros(s), v=jnp.zeros(s))",
           '{"k_scale": jnp.ones(lead + (hkv,), jnp.float32)}',
           "pool = {'v_scale': jnp.full(s, 1.0)}"]
    good = ['"ln_scale": jnp.ones((H, hd), jnp.float32),',
            "k = jnp.zeros((4, 4))",
            'cache["k"] = quantized',
            '{"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}',
            "kv = dict(k=new_k, v=new_v)"]
    for s in bad:
        assert _KV_POOL_ALLOC.search(s), s
    for s in good:
        assert not _KV_POOL_ALLOC.search(s), s


_SPEC_STATE = re.compile(r"\._draft_state\b|select_checkpoint\s*\(")
_SPEC_ALLOWED = ("spec.py", "state_providers.py")


def test_spec_state_stays_in_spec_and_state_providers():
    """Draft cursors live in the drafters (engine/spec.py); recurrent
    rollback checkpoints are selected only by state_providers. The engine
    talks to both through propose/forget and verify_step."""
    offenders = []
    for root in (SERVING, MODELS):
        assert root.is_dir()
        for path in sorted(root.rglob("*.py")):
            if path.name in _SPEC_ALLOWED:
                continue
            for lineno, line in enumerate(path.read_text().splitlines(), 1):
                if _SPEC_STATE.search(line):
                    offenders.append(f"{path.relative_to(root.parent)}:"
                                     f"{lineno}: {line.strip()}")
    assert not offenders, (
        "speculative-decoding state touched outside engine/spec.py / "
        "state_providers.py (use Drafter.propose/forget and "
        "spec.verify_step):\n" + "\n".join(offenders))


def test_spec_lint_regex_catches_the_banned_patterns():
    bad = ["drafter._draft_state[rid] = 3",
           "del self.drafter._draft_state[rid]",
           "cp = SP.select_checkpoint(aux, accepts, old)",
           "state_providers.select_checkpoint (checkpoints, a, o)"]
    good = ["self.drafter.forget(rid)",
            "drafter.propose(rid, ctx, k - 1)",
            "self._draft_state2 = {}",
            "checkpoint = select_checkpoints[0]"]
    for s in bad:
        assert _SPEC_STATE.search(s), s
    for s in good:
        assert not _SPEC_STATE.search(s), s


def test_pool_lint_regex_catches_the_banned_patterns():
    bad = ["pool._ref[b] -= 1",
           "del self.block_pool._owned[rid]",
           "pool._free.append(b)",
           "pool._index.pop(h)",
           "k = pool._hash_of[b]",
           "pool._n_cached_free += 1"]
    good = ["pool.num_free == 4",
            "self._free_slots.pop()",
            "pool.free_seq(rid)",
            "self._refresh()",
            "self._m_prefill_deferrals.inc()"]
    for s in bad:
        assert _POOL_INTERNAL.search(s), s
    for s in good:
        assert not _POOL_INTERNAL.search(s), s
