"""Repo lint: serving metrics must flow through the telemetry registry.

Any raw mutation of an ad-hoc stats dict (``self.stats["x"] += 1`` and
friends) inside ``src/repro/serving/`` is a regression back to the three
scattered dicts the registry superseded — only telemetry.py may own metric
state."""
import pathlib
import re

import pytest

pytestmark = [pytest.mark.serving, pytest.mark.telemetry]

SERVING = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro" / "serving"

# .stats[...] followed by an (augmented) assignment; `==` comparisons and
# plain reads don't match because they aren't followed by an assignment op.
_RAW_STATS_MUTATION = re.compile(
    r"\.stats\[[^\]]+\]\s*(?:[-+*/|&^%]|//|>>|<<)?=(?!=)")


def test_no_raw_stats_mutations_outside_telemetry():
    assert SERVING.is_dir()
    offenders = []
    for path in sorted(SERVING.rglob("*.py")):
        if path.name == "telemetry.py":
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if _RAW_STATS_MUTATION.search(line):
                offenders.append(f"{path.relative_to(SERVING)}:{lineno}: "
                                 f"{line.strip()}")
    assert not offenders, (
        "raw stats-dict mutations found (route through the telemetry "
        "MetricsRegistry instead):\n" + "\n".join(offenders))


def test_lint_regex_catches_the_banned_patterns():
    bad = ['self.stats["lookups"] += 1',
           "pool.stats['evictions'] = 0",
           'self.stats["x"] //= 2']
    good = ['assert eng.stats["emitted"] == 6',
            'hits = pool.stats["hit_blocks"]',
            'if self.stats["lookups"] == 0:']
    for s in bad:
        assert _RAW_STATS_MUTATION.search(s), s
    for s in good:
        assert not _RAW_STATS_MUTATION.search(s), s
