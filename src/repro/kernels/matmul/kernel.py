"""Blocked GEMM Pallas TPU kernel — the MXU-native core of every FC layer
(survey §4.2: "fully connected layers as matrix multiplication").

Tiling: grid (M/bm, N/bn, K/bk); each (i, j) output tile accumulates over the
k grid dimension in an f32 VMEM scratch accumulator and writes back once.
HBM→VMEM traffic is bm·bk + bk·bn per k-step plus bm·bn once — the standard
roofline-optimal schedule. Defaults 256/256/512 keep the working set
(~1.2 MB in bf16 + 256 KB f32 accumulator) comfortably inside the ~16 MB
VMEM while all MXU dims are 128-multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(a, b, *, block_m=256, block_n=256, block_k=512,
                  out_dtype=None, interpret=False):
    """a: (M, K) @ b: (K, N) -> (M, N). Block sizes clamp to the dims and
    must then divide them."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, \
        (a.shape, b.shape, block_m, block_n, block_k)
    k_steps = K // block_k
    out_dtype = out_dtype or a.dtype

    return pl.pallas_call(
        functools.partial(matmul_kernel, k_steps=k_steps),
        grid=(M // block_m, N // block_n, k_steps),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
