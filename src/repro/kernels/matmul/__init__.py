from repro.kernels.matmul.ops import matmul  # noqa: F401
from repro.kernels.matmul.ref import matmul_ref  # noqa: F401
