"""jit'd public wrapper for the blocked GEMM kernel.

On non-TPU backends (this CPU container) `interpret=True` executes the kernel
body in Python — the validation mode used by the kernel test sweeps."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.matmul.kernel import matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def matmul(a, b, *, block_m=256, block_n=256, block_k=512, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return matmul_pallas(a, b, block_m=block_m, block_n=block_n,
                         block_k=block_k, interpret=interpret)
