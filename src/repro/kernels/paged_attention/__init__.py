from repro.kernels.paged_attention.ops import (
    paged_attention, paged_attention_verify)
from repro.kernels.paged_attention.ref import (
    paged_attention_ref, paged_attention_verify_ref)

__all__ = ["paged_attention", "paged_attention_ref",
           "paged_attention_verify", "paged_attention_verify_ref"]
