"""jit'd wrapper: paged decode attention over block-pooled KV layouts.

Full mode attends the whole logical prefix through the block table; ring
mode (window/positions/ring_pages set) attends the sliding window
(position - window, position] through a fixed ring of `ring_pages` blocks.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.paged_attention.kernel import (
    paged_attention_pallas, paged_attention_verify_pallas)
from repro.kernels.paged_attention.ref import paged_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("interpret", "window", "ring_pages"))
def paged_attention(q, k_pool, v_pool, block_tables, seq_lens, *,
                    window=None, positions=None, ring_pages=None,
                    k_scale=None, v_scale=None, interpret=None):
    """q: (B, H, hd); k_pool/v_pool: (N, block_size, Hkv, hd); block_tables:
    (B, P) int32; seq_lens: (B,) int32 — valid tokens per sequence including
    the current one (0 marks an inactive slot). Ring mode: `window` and
    `ring_pages` are static, `positions` (B,) carries each sequence's
    current absolute position. k_scale/v_scale: (N, block_size, Hkv) f32
    dequant scales when the pools are int8. Returns (B, H, hd)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return paged_attention_pallas(q, k_pool, v_pool, block_tables, seq_lens,
                                  window=window, positions=positions,
                                  ring_pages=ring_pages, k_scale=k_scale,
                                  v_scale=v_scale, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret", "window", "ring_pages"))
def paged_attention_verify(q, k_pool, v_pool, block_tables, seq_lens, *,
                           window=None, positions=None, ring_pages=None,
                           k_scale=None, v_scale=None, interpret=None):
    """Multi-query verify mode for speculative decoding. q: (B, K, H, hd) —
    K draft queries per sequence, all K/V already written. ``seq_lens``
    counts tokens INCLUDING the K drafts; query j attends causally up to
    position ``seq_lens - K + j``. Ring mode: ``positions = seq_lens - 1``
    and the ring sized with ``draft = K - 1`` slack. k_scale/v_scale: int8
    dequant scales as in :func:`paged_attention`. Returns (B, K, H, hd)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return paged_attention_verify_pallas(
        q, k_pool, v_pool, block_tables, seq_lens, window=window,
        positions=positions, ring_pages=ring_pages, k_scale=k_scale,
        v_scale=v_scale, interpret=interpret)
