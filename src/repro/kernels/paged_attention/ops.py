"""jit'd wrapper: paged decode attention over block-pooled KV layouts."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.ref import paged_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pool, v_pool, block_tables, seq_lens, *,
                    interpret=None):
    """q: (B, H, hd); k_pool/v_pool: (N, block_size, Hkv, hd); block_tables:
    (B, P) int32; seq_lens: (B,) int32 — valid tokens per sequence including
    the current one (0 marks an inactive slot). Returns (B, H, hd)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return paged_attention_pallas(q, k_pool, v_pool, block_tables, seq_lens,
                                  interpret=interpret)
