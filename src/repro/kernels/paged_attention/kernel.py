"""Paged decode attention Pallas TPU kernel.

The serving engine stores KV in fixed-size blocks of a shared pool; each
sequence owns a list of block ids (its *block table*). At decode time one
query token per sequence must attend over its logically-contiguous KV, which
is physically scattered across the pool.

The kernel uses `PrefetchScalarGridSpec`: the block table and sequence
lengths are scalar-prefetched so the BlockSpec index maps can address the
*physical* KV block for grid step (b, p) — the DMA engine walks the block
table, no host-side gather materializes the sequence. Running online-softmax
statistics (m, l, acc) live in VMEM scratch that persists across the page
steps of one sequence, exactly like the flash_attention kernel's kv axis.

Grid: (B, P) with the page axis innermost ("arbitrary" semantics). Pages at
or beyond seq_len are skipped (`pl.when`), so the work per sequence is
O(seq_len), not O(P * block_size).

Ring mode (`window` + `ring_pages` set, `positions` prefetched as a third
scalar array): sliding-window layers keep a fixed ring of `ring_pages`
blocks per sequence — token at absolute position p lives at
`table[(p // bs) % R]`, offset `p % bs`. The grid's page axis shrinks to R
and each grid step reconstructs the absolute page its ring slot currently
holds (`q_cur - ((q_cur % R - r) % R)`), masking keys outside
`(position - window, position]`. Stale previous-lap offsets in the current
page reconstruct to positions > position, so the causal bound masks them;
pages wholly outside the window (or not yet written) are skipped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _load_kv(ref, sref):
    """Load one pool block (1, bs, Hkv, hd) as f32 (Hkv, bs, hd). When the
    pool is int8 (`sref` holds per-(slot, head) scales, block (1, bs, Hkv)),
    the dequant multiply happens here — inside the kernel, after the DMA — so
    HBM traffic on the decode hot path is the int8 bytes, not f32."""
    x = ref[0].astype(jnp.float32)
    if sref is not None:
        x = x * sref[0][..., None]                   # (bs, Hkv, 1) broadcast
    return x.swapaxes(0, 1)


def paged_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, *rest, scale,
                 block_size, pages, groups, quant=False):
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    p = pl.program_id(1)
    seq_len = lens_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(p * block_size < seq_len)
    def _compute():
        H, hd = q_ref.shape[1], q_ref.shape[2]
        Hkv = H // groups
        q = q_ref[0].astype(jnp.float32).reshape(Hkv, groups, hd)
        k = _load_kv(k_ref, ks_ref)                                # (Hkv, bs, hd)
        v = _load_kv(v_ref, vs_ref)
        # batched over kv heads: (Hkv, g, hd) x (Hkv, bs, hd) -> (Hkv, g, bs)
        s = jax.lax.dot_general(
            q, k, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale
        kpos = p * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (Hkv, groups, block_size), 2)
        s = jnp.where(kpos < seq_len, s, NEG_INF)

        m_prev = m_ref[...]                                        # (Hkv, g, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
        prob = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(prob, axis=2, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            prob, v, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)                    # (Hkv, g, hd)
        m_ref[...] = m_new

    @pl.when(p == pages - 1)
    def _finish():
        H, hd = o_ref.shape[1], o_ref.shape[2]
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).reshape(H, hd).astype(o_ref.dtype)


def paged_verify_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, *rest,
                        scale, block_size, pages, groups, n_q, quant=False):
    """Multi-query verify body: grid (B, P), q block (1, n_q, H, hd).

    ``lens_ref[b]`` counts tokens INCLUDING the n_q draft tokens, so query
    row j sits at absolute position ``lens - n_q + j`` and is masked to keys
    ``kpos <= lens - n_q + j`` — causal among the draft positions and over
    the committed prefix. Online-softmax rows are laid out (Hkv, n_q*groups)
    so each row runs exactly the decode kernel's elementwise schedule;
    fully-masked pages leave (m, l, acc) bit-unchanged."""
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    p = pl.program_id(1)
    seq_len = lens_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(p * block_size < seq_len)
    def _compute():
        H, hd = q_ref.shape[2], q_ref.shape[3]
        Hkv = H // groups
        rows = n_q * groups
        # (n_q, H, hd) -> (Hkv, n_q*groups, hd): kv-head-major rows
        q = (q_ref[0].astype(jnp.float32)
             .reshape(n_q, Hkv, groups, hd)
             .transpose(1, 0, 2, 3)
             .reshape(Hkv, rows, hd))
        k = _load_kv(k_ref, ks_ref)                                # (Hkv, bs, hd)
        v = _load_kv(v_ref, vs_ref)
        s = jax.lax.dot_general(
            q, k, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale            # (Hkv, rows, bs)
        kpos = p * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (Hkv, rows, block_size), 2)
        row = jax.lax.broadcasted_iota(jnp.int32, (Hkv, rows, block_size), 1)
        qpos = seq_len - n_q + row // groups
        s = jnp.where(kpos <= qpos, s, NEG_INF)

        m_prev = m_ref[...]                                        # (Hkv, rows, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
        prob = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(prob, axis=2, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            prob, v, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)                    # (Hkv, rows, hd)
        m_ref[...] = m_new

    @pl.when(p == pages - 1)
    def _finish():
        H, hd = o_ref.shape[2], o_ref.shape[3]
        Hkv = H // groups
        denom = jnp.maximum(l_ref[...], 1e-30)
        acc = (acc_ref[...] / denom).reshape(Hkv, n_q, groups, hd)
        o_ref[0] = acc.transpose(1, 0, 2, 3).reshape(n_q, H, hd).astype(
            o_ref.dtype)


def paged_ring_verify_kernel(tables_ref, lens_ref, pos_ref, q_ref, k_ref,
                             v_ref, *rest, scale, block_size, pages, groups,
                             window, n_q, quant=False):
    """Ring-mode multi-query verify body: grid (B, R). ``pos_ref[b]`` is the
    NEWEST draft position (``lens - 1``); query row j sits at
    ``pos - (n_q - 1) + j`` and is masked to its own sliding window. The
    caller must size the ring with ``draft = n_q - 1`` slack so the oldest
    query's window is still resident."""
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    r = pl.program_id(1)
    pos = pos_ref[b]
    q_cur = pos // block_size
    page = q_cur - ((q_cur % pages - r) % pages)
    base = page * block_size

    @pl.when(r == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # live iff the page intersects the union of the n_q query windows:
    # keys in (pos - (n_q - 1) - window, pos]
    live = ((lens_ref[b] > 0) & (page >= 0) & (base <= pos)
            & (base + block_size - 1 > pos - (n_q - 1) - window))

    @pl.when(live)
    def _compute():
        H, hd = q_ref.shape[2], q_ref.shape[3]
        Hkv = H // groups
        rows = n_q * groups
        q = (q_ref[0].astype(jnp.float32)
             .reshape(n_q, Hkv, groups, hd)
             .transpose(1, 0, 2, 3)
             .reshape(Hkv, rows, hd))
        k = _load_kv(k_ref, ks_ref)
        v = _load_kv(v_ref, vs_ref)
        s = jax.lax.dot_general(
            q, k, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale
        kpos = base + jax.lax.broadcasted_iota(
            jnp.int32, (Hkv, rows, block_size), 2)
        row = jax.lax.broadcasted_iota(jnp.int32, (Hkv, rows, block_size), 1)
        qpos = pos - (n_q - 1) + row // groups
        s = jnp.where((kpos <= qpos) & (kpos > qpos - window), s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
        prob = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(prob, axis=2, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            prob, v, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(r == pages - 1)
    def _finish():
        H, hd = o_ref.shape[2], o_ref.shape[3]
        Hkv = H // groups
        denom = jnp.maximum(l_ref[...], 1e-30)
        acc = (acc_ref[...] / denom).reshape(Hkv, n_q, groups, hd)
        o_ref[0] = acc.transpose(1, 0, 2, 3).reshape(n_q, H, hd).astype(
            o_ref.dtype)


def paged_ring_kernel(tables_ref, lens_ref, pos_ref, q_ref, k_ref, v_ref,
                      *rest, scale, block_size, pages, groups, window,
                      quant=False):
    """Ring-mode body: grid (B, R). `pages` is the ring length R; `pos_ref`
    holds each sequence's current absolute position (scalar-prefetched so
    the index map can still walk the block table)."""
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    r = pl.program_id(1)
    pos = pos_ref[b]
    q_cur = pos // block_size
    # absolute page currently held by ring slot r (negative: never written)
    page = q_cur - ((q_cur % pages - r) % pages)
    base = page * block_size

    @pl.when(r == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = ((lens_ref[b] > 0) & (page >= 0) & (base <= pos)
            & (base + block_size - 1 > pos - window))

    @pl.when(live)
    def _compute():
        H, hd = q_ref.shape[1], q_ref.shape[2]
        Hkv = H // groups
        q = q_ref[0].astype(jnp.float32).reshape(Hkv, groups, hd)
        k = _load_kv(k_ref, ks_ref)                                # (Hkv, bs, hd)
        v = _load_kv(v_ref, vs_ref)
        s = jax.lax.dot_general(
            q, k, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale
        kpos = base + jax.lax.broadcasted_iota(
            jnp.int32, (Hkv, groups, block_size), 2)
        # stale previous-lap offsets in the current page have kpos > pos
        s = jnp.where((kpos <= pos) & (kpos > pos - window), s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
        prob = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(prob, axis=2, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            prob, v, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(r == pages - 1)
    def _finish():
        H, hd = o_ref.shape[1], o_ref.shape[2]
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).reshape(H, hd).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pool, v_pool, block_tables, seq_lens, *,
                           scale=None, window=None, positions=None,
                           ring_pages=None, k_scale=None, v_scale=None,
                           interpret=False):
    """q: (B, H, hd); k_pool/v_pool: (N, bs, Hkv, hd) with H % Hkv == 0;
    block_tables: (B, P) int32; seq_lens: (B,) int32 (0 = inactive slot,
    current token already written to the pool). Returns (B, H, hd).

    window/positions/ring_pages (all three) switch to ring mode: the page
    grid axis becomes `ring_pages` and keys are masked to the sliding
    window (positions - window, positions].

    k_scale/v_scale (both or neither): int8 pools with per-(slot, head) f32
    scales (N, bs, Hkv), dequantized inside the kernel — the scale BlockSpecs
    walk the same block table as the pools."""
    B, H, hd = q.shape
    N, bs, Hkv, _ = k_pool.shape
    P = block_tables.shape[1]
    groups = H // Hkv
    scale = scale if scale is not None else hd ** -0.5
    quant = k_scale is not None
    if quant != (v_scale is not None):
        raise ValueError("k_scale and v_scale must be passed together")

    if window is not None:
        if positions is None or ring_pages is None:
            raise ValueError("ring mode needs window, positions AND ring_pages")
        R = ring_pages
        kern = functools.partial(
            paged_ring_kernel, scale=scale, block_size=bs, pages=R,
            groups=groups, window=window, quant=quant)
        in_specs = [
            pl.BlockSpec((1, H, hd), lambda b, p, tbl, lens, pos: (b, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, hd),
                         lambda b, p, tbl, lens, pos: (tbl[b, p], 0, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, hd),
                         lambda b, p, tbl, lens, pos: (tbl[b, p], 0, 0, 0)),
        ]
        operands = [q, k_pool, v_pool]
        if quant:
            in_specs += [
                pl.BlockSpec((1, bs, Hkv),
                             lambda b, p, tbl, lens, pos: (tbl[b, p], 0, 0)),
                pl.BlockSpec((1, bs, Hkv),
                             lambda b, p, tbl, lens, pos: (tbl[b, p], 0, 0)),
            ]
            operands += [k_scale, v_scale]
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, R),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, H, hd),
                                   lambda b, p, tbl, lens, pos: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((Hkv, groups, 1), jnp.float32),
                pltpu.VMEM((Hkv, groups, 1), jnp.float32),
                pltpu.VMEM((Hkv, groups, hd), jnp.float32),
            ],
        )
        return pl.pallas_call(
            kern,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
            interpret=interpret,
        )(block_tables, seq_lens, positions.astype(jnp.int32), *operands)

    kern = functools.partial(
        paged_kernel, scale=scale, block_size=bs, pages=P, groups=groups,
        quant=quant)
    in_specs = [
        pl.BlockSpec((1, H, hd), lambda b, p, tbl, lens: (b, 0, 0)),
        pl.BlockSpec((1, bs, Hkv, hd),
                     lambda b, p, tbl, lens: (tbl[b, p], 0, 0, 0)),
        pl.BlockSpec((1, bs, Hkv, hd),
                     lambda b, p, tbl, lens: (tbl[b, p], 0, 0, 0)),
    ]
    operands = [q, k_pool, v_pool]
    if quant:
        in_specs += [
            pl.BlockSpec((1, bs, Hkv),
                         lambda b, p, tbl, lens: (tbl[b, p], 0, 0)),
            pl.BlockSpec((1, bs, Hkv),
                         lambda b, p, tbl, lens: (tbl[b, p], 0, 0)),
        ]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, H, hd), lambda b, p, tbl, lens: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, groups, 1), jnp.float32),
            pltpu.VMEM((Hkv, groups, 1), jnp.float32),
            pltpu.VMEM((Hkv, groups, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(block_tables, seq_lens, *operands)


def paged_attention_verify_pallas(q, k_pool, v_pool, block_tables, seq_lens,
                                  *, scale=None, window=None, positions=None,
                                  ring_pages=None, k_scale=None, v_scale=None,
                                  interpret=False):
    """Multi-query verify: q: (B, K, H, hd) — K draft queries per sequence,
    K/V already written (write-then-attend). ``seq_lens`` counts tokens
    INCLUDING the K draft tokens; query j attends keys up to position
    ``seq_lens - K + j``. Active slots must satisfy ``seq_lens >= K``.
    Ring mode: ``positions = seq_lens - 1`` (newest draft position) and the
    ring must be sized with ``draft = K - 1`` slack. Returns (B, K, H, hd).
    k_scale/v_scale: int8-pool dequant scales, as in paged_attention_pallas."""
    B, K, H, hd = q.shape
    N, bs, Hkv, _ = k_pool.shape
    P = block_tables.shape[1]
    groups = H // Hkv
    rows = K * groups
    scale = scale if scale is not None else hd ** -0.5
    quant = k_scale is not None
    if quant != (v_scale is not None):
        raise ValueError("k_scale and v_scale must be passed together")

    if window is not None:
        if positions is None or ring_pages is None:
            raise ValueError("ring mode needs window, positions AND ring_pages")
        R = ring_pages
        kern = functools.partial(
            paged_ring_verify_kernel, scale=scale, block_size=bs, pages=R,
            groups=groups, window=window, n_q=K, quant=quant)
        in_specs = [
            pl.BlockSpec((1, K, H, hd),
                         lambda b, p, tbl, lens, pos: (b, 0, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, hd),
                         lambda b, p, tbl, lens, pos: (tbl[b, p], 0, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, hd),
                         lambda b, p, tbl, lens, pos: (tbl[b, p], 0, 0, 0)),
        ]
        operands = [q, k_pool, v_pool]
        if quant:
            in_specs += [
                pl.BlockSpec((1, bs, Hkv),
                             lambda b, p, tbl, lens, pos: (tbl[b, p], 0, 0)),
                pl.BlockSpec((1, bs, Hkv),
                             lambda b, p, tbl, lens, pos: (tbl[b, p], 0, 0)),
            ]
            operands += [k_scale, v_scale]
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, R),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, K, H, hd),
                                   lambda b, p, tbl, lens, pos: (b, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((Hkv, rows, 1), jnp.float32),
                pltpu.VMEM((Hkv, rows, 1), jnp.float32),
                pltpu.VMEM((Hkv, rows, hd), jnp.float32),
            ],
        )
        return pl.pallas_call(
            kern,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((B, K, H, hd), q.dtype),
            interpret=interpret,
        )(block_tables, seq_lens, positions.astype(jnp.int32), *operands)

    kern = functools.partial(
        paged_verify_kernel, scale=scale, block_size=bs, pages=P,
        groups=groups, n_q=K, quant=quant)
    in_specs = [
        pl.BlockSpec((1, K, H, hd), lambda b, p, tbl, lens: (b, 0, 0, 0)),
        pl.BlockSpec((1, bs, Hkv, hd),
                     lambda b, p, tbl, lens: (tbl[b, p], 0, 0, 0)),
        pl.BlockSpec((1, bs, Hkv, hd),
                     lambda b, p, tbl, lens: (tbl[b, p], 0, 0, 0)),
    ]
    operands = [q, k_pool, v_pool]
    if quant:
        in_specs += [
            pl.BlockSpec((1, bs, Hkv),
                         lambda b, p, tbl, lens: (tbl[b, p], 0, 0)),
            pl.BlockSpec((1, bs, Hkv),
                         lambda b, p, tbl, lens: (tbl[b, p], 0, 0)),
        ]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, K, H, hd),
                               lambda b, p, tbl, lens: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, rows, 1), jnp.float32),
            pltpu.VMEM((Hkv, rows, 1), jnp.float32),
            pltpu.VMEM((Hkv, rows, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, H, hd), q.dtype),
        interpret=interpret,
    )(block_tables, seq_lens, *operands)
