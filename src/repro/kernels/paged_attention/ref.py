"""Pure-jnp oracle for paged decode attention.

One query token per sequence attends over KV stored in a block pool via a
per-sequence block table. Semantics:

  * ``seq_lens[b]`` counts the valid tokens of sequence ``b`` INCLUDING the
    current one — the caller writes the current token's K/V into the pool
    *before* calling (same write-then-attend order as
    ``models.attention.attention_decode``).
  * ``seq_lens[b] == 0`` marks an inactive slot: the output row is all zeros.
  * Table entries past the sequence's last page may point anywhere inside the
    pool; their contents are masked out.
"""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k_pool, v_pool, block_tables, seq_lens, *, scale=None):
    """q: (B, H, hd); k_pool/v_pool: (N, bs, Hkv, hd);
    block_tables: (B, P) int32; seq_lens: (B,) int32. Returns (B, H, hd)."""
    B, H, hd = q.shape
    N, bs, Hkv, _ = k_pool.shape
    P = block_tables.shape[1]
    g = H // Hkv
    scale = scale if scale is not None else hd ** -0.5

    # gather pages -> contiguous (B, P*bs, Hkv, hd) view of each sequence;
    # GQA stays grouped (no repeated K/V materialization)
    k = k_pool[block_tables].reshape(B, P * bs, Hkv, hd)
    v = v_pool[block_tables].reshape(B, P * bs, Hkv, hd)
    qg = q.reshape(B, Hkv, g, hd)

    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale                 # (B,Hkv,g,K)
    valid = jnp.arange(P * bs)[None, :] < seq_lens[:, None]       # (B, P*bs)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    # max-subtracted softmax with a guarded denominator so fully-masked rows
    # (inactive slots) produce zeros instead of NaN
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jnp.maximum(m, NEG_INF / 2))
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgk,bkhd->bhgd", p / denom, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
