"""Pure-jnp oracle for paged decode attention (full and ring/sliding-window).

One query token per sequence attends over KV stored in a block pool via a
per-sequence block table. Semantics:

  * ``seq_lens[b]`` counts the valid tokens of sequence ``b`` INCLUDING the
    current one — the caller writes the current token's K/V into the pool
    *before* calling (same write-then-attend order as
    ``models.attention.attention_decode``).
  * ``seq_lens[b] == 0`` marks an inactive slot: the output row is all zeros.
  * Table entries past the sequence's last page may point anywhere inside the
    pool; their contents are masked out.

Ring mode (``window`` + ``positions`` + ``ring_pages`` set): the sequence
only owns ``ring_pages`` blocks and token at absolute position p was written
at ``table[(p // bs) % ring_pages]``, offset ``p % bs``. The oracle inverts
that mapping — ring slot r currently holds absolute page
``q_cur - ((q_cur % R - r) % R)`` where ``q_cur = position // bs`` — and
attends exactly the window ``(position - window, position]``. Offsets past
``position % bs`` in the current page still hold the previous lap's keys;
their reconstructed positions exceed ``position`` so the causal bound masks
them.
"""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _masked_gqa_attend(q, k, v, valid, scale):
    """q: (B, H, hd); k/v: (B, K, Hkv, hd); valid: (B, K) bool mask.
    Max-subtracted softmax with a guarded denominator so fully-masked rows
    (inactive slots) produce zeros instead of NaN. Returns (B, H, hd)."""
    B, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale                 # (B,Hkv,g,K)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jnp.maximum(m, NEG_INF / 2))
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgk,bkhd->bhgd", p / denom, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def _masked_gqa_attend_multi(q, k, v, valid, scale):
    """Multi-query variant: q: (B, K, H, hd); k/v: (B, Kk, Hkv, hd);
    valid: (B, K, Kk) bool, one key mask per query row. Each row runs the
    exact elementwise ops of :func:`_masked_gqa_attend`, so a verify row is
    bit-identical to the single-query reference at the same position.
    Returns (B, K, H, hd)."""
    B, K, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, K, Hkv, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale            # (B,K,Hkv,g,Kk)
    mask = valid[:, :, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jnp.maximum(m, NEG_INF / 2))
    p = jnp.where(mask, p, 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p / denom, v.astype(jnp.float32))
    return out.reshape(B, K, H, hd).astype(q.dtype)


def _gather_pool(pool, scl, tables, T):
    """Gather pool blocks through a block table into (B, T, Hkv, hd) f32.
    When ``scl`` (N, bs, Hkv) is given the pool is int8 and each vector is
    dequantized with the same per-(slot, head) multiply as the Pallas
    kernel's `_load_kv` — so ref-with-scales is bitwise identical to the ref
    run on a pre-dequantized f32 pool."""
    B = tables.shape[0]
    Hkv, hd = pool.shape[2], pool.shape[3]
    x = pool[tables].astype(jnp.float32)                 # (B, P, bs, Hkv, hd)
    if scl is not None:
        x = x * scl[tables][..., None]
    return x.reshape(B, T, Hkv, hd)


def ring_key_positions(positions, ring_pages, block_size):
    """Absolute position of every (ring slot, offset) pair, per sequence.
    positions: (B,) current absolute position. Returns (B, R*bs) int32;
    entries may be negative (page not yet written) or > positions (stale
    previous-lap offsets) — callers mask both."""
    R, bs = ring_pages, block_size
    q_cur = positions // bs                                       # (B,)
    r_cur = q_cur % R
    page = q_cur[:, None] - ((r_cur[:, None] - jnp.arange(R)[None, :]) % R)
    kpos = page[:, :, None] * bs + jnp.arange(bs)[None, None, :]  # (B, R, bs)
    return kpos.reshape(positions.shape[0], R * bs)


def paged_attention_ref(q, k_pool, v_pool, block_tables, seq_lens, *,
                        scale=None, window=None, positions=None,
                        ring_pages=None, k_scale=None, v_scale=None):
    """q: (B, H, hd); k_pool/v_pool: (N, bs, Hkv, hd);
    block_tables: (B, P) int32; seq_lens: (B,) int32. Returns (B, H, hd).

    window/positions/ring_pages switch on ring mode (all three required):
    attend the sliding window (positions - window, positions] through the
    ring block layout. k_scale/v_scale: int8-pool dequant scales
    (N, bs, Hkv) f32."""
    B, H, hd = q.shape
    N, bs, Hkv, _ = k_pool.shape
    scale = scale if scale is not None else hd ** -0.5

    if window is None:
        P = block_tables.shape[1]
        k = _gather_pool(k_pool, k_scale, block_tables, P * bs)
        v = _gather_pool(v_pool, v_scale, block_tables, P * bs)
        valid = jnp.arange(P * bs)[None, :] < seq_lens[:, None]
        return _masked_gqa_attend(q, k, v, valid, scale)

    if positions is None or ring_pages is None:
        raise ValueError("ring mode needs window, positions AND ring_pages")
    R = ring_pages
    tables = block_tables[:, :R]
    k = _gather_pool(k_pool, k_scale, tables, R * bs)
    v = _gather_pool(v_pool, v_scale, tables, R * bs)
    kpos = ring_key_positions(positions, R, bs)                   # (B, R*bs)
    valid = ((kpos >= 0)
             & (kpos <= positions[:, None])
             & (kpos > positions[:, None] - window)
             & (seq_lens[:, None] > 0))
    return _masked_gqa_attend(q, k, v, valid, scale)


def paged_attention_verify_ref(q, k_pool, v_pool, block_tables, seq_lens, *,
                               scale=None, window=None, positions=None,
                               ring_pages=None, k_scale=None, v_scale=None):
    """Multi-query verify oracle for speculative decoding.

    q: (B, K, H, hd) — K draft queries per sequence. ``seq_lens[b]`` counts
    valid tokens INCLUDING all K draft tokens (their K/V already written,
    write-then-attend), so query j of sequence b sits at absolute position
    ``seq_lens[b] - K + j`` and attends keys causally up to and including
    itself. ``seq_lens[b] == 0`` marks an inactive slot (zero output).

    Ring mode (window/positions/ring_pages set): ``positions[b]`` is the
    NEWEST draft position ``seq_lens[b] - 1``; each query attends its own
    sliding window ``(qpos - window, qpos]`` through the ring layout. The
    caller is responsible for sizing the ring so that the oldest query's
    window is still resident (``ring_pages(window, bs, draft=K-1)``).
    Returns (B, K, H, hd)."""
    B, K, H, hd = q.shape
    N, bs, Hkv, _ = k_pool.shape
    scale = scale if scale is not None else hd ** -0.5
    qpos = seq_lens[:, None] - K + jnp.arange(K)[None, :]         # (B, K)

    if window is None:
        P = block_tables.shape[1]
        k = _gather_pool(k_pool, k_scale, block_tables, P * bs)
        v = _gather_pool(v_pool, v_scale, block_tables, P * bs)
        kpos = jnp.arange(P * bs)
        valid = kpos[None, None, :] <= qpos[:, :, None]           # (B, K, P*bs)
        return _masked_gqa_attend_multi(q, k, v, valid, scale)

    if positions is None or ring_pages is None:
        raise ValueError("ring mode needs window, positions AND ring_pages")
    R = ring_pages
    tables = block_tables[:, :R]
    k = _gather_pool(k_pool, k_scale, tables, R * bs)
    v = _gather_pool(v_pool, v_scale, tables, R * bs)
    kpos = ring_key_positions(positions, R, bs)                   # (B, R*bs)
    valid = ((kpos[:, None, :] >= 0)
             & (kpos[:, None, :] <= qpos[:, :, None])
             & (kpos[:, None, :] > qpos[:, :, None] - window)
             & (seq_lens[:, None, None] > 0))
    return _masked_gqa_attend_multi(q, k, v, valid, scale)
