"""Pure-jnp oracle: materialized-scores softmax attention with the same
masking semantics as the flash kernel."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, scale=None, causal=True, window=None):
    """q, k, v: (BH, S, hd) -> (BH, S, hd)."""
    BH, S, hd = q.shape
    scale = scale if scale is not None else hd ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
