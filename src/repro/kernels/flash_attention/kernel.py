"""Flash-attention Pallas TPU kernel: online-softmax tiling, causal and
sliding-window masking.

This is the TPU adaptation of the survey's memory/recompute trade-off
analysis (§4.4, Gruslys et al. BPTT; §4.3 locality): attention is computed
in (block_q × block_k) VMEM tiles with running max/sum statistics so the
(S × S) score matrix never exists in HBM — the memory term drops from
O(S²) to O(S·hd), turning the prefill_32k shape from memory-bound to
compute-bound (see EXPERIMENTS §Perf).

Grid: (BH, S/bq, S/bk) — the kv axis is innermost ("arbitrary" semantics);
running statistics (m, l, acc) live in VMEM scratch that persists across the
kv steps of one (bh, q) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale, block_q, block_k, kv_steps, causal, window):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # skip fully-masked tiles (causal: tile strictly above diagonal;
    # window: tile strictly left of the window's reach)
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window is not None:
        needed = jnp.logical_and(needed,
                                 k_start + block_k - 1 > q_start - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, scale=None, causal=True, window=None,
                           block_q=256, block_k=256, interpret=False):
    """q, k, v: (BH, S, hd) with matching head counts (GQA expanded by ops).
    Returns (BH, S, hd)."""
    BH, S, hd = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    scale = scale if scale is not None else hd ** -0.5
    kv_steps = S // block_k

    kern = functools.partial(
        flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        kv_steps=kv_steps, causal=causal, window=window)
    return pl.pallas_call(
        kern,
        grid=(BH, S // block_q, kv_steps),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
