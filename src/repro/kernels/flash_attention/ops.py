"""jit'd wrapper: GQA-aware flash attention over (B, S, H, hd) layouts."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=256,
                    block_k=256, interpret=None):
    """q: (B, S, H, hd); k, v: (B, S, Hkv, hd) with H % Hkv == 0.
    Returns (B, S, H, hd)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    to_bh = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    out = flash_attention_pallas(
        to_bh(q), to_bh(k), to_bh(v), causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
