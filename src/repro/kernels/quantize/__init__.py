from repro.kernels.quantize.ops import (  # noqa: F401
    KVQuantConfig,
    dequantize_blocks,
    dequantize_kv,
    quantize_blocks,
    quantize_kv,
)
from repro.kernels.quantize.ref import quantize_blocks_ref  # noqa: F401
