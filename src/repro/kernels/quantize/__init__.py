from repro.kernels.quantize.ops import quantize_blocks, dequantize_blocks  # noqa: F401
from repro.kernels.quantize.ref import quantize_blocks_ref  # noqa: F401
