"""Block-scaled int8 stochastic-rounding quantizer — Pallas TPU kernel for
the gradient-compression hot spot (survey §6.3.1, QSGD / Gupta et al.).

Every gradient bucket of `block` contiguous values is scaled by max|g|/127
and stochastically rounded to int8: E[dequant(quant(g))] = g, the survey's
convergence condition. On an allreduce path this runs on the full gradient
every step — bandwidth-bound, so the kernel streams rows of buckets through
VMEM in one pass (read f32, write int8 + one f32 scale per bucket: a 3.9×
wire/HBM reduction).

Uniform noise is an explicit operand (deterministic, testable vs ref.py);
on-device RNG (pltpu.prng_random_bits) is a drop-in for production.

Grid: (rows/block_rows,); each step quantizes (block_rows, block) values.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def quantize_kernel(x_ref, u_ref, q_ref, s_ref, *, maxq):
    x = x_ref[...].astype(jnp.float32)               # (bm, block)
    u = u_ref[...]
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax == 0.0, 1.0, amax / maxq)
    y = x / scale
    lo = jnp.floor(y)
    p = y - lo
    q = lo + (u < p).astype(jnp.float32)
    q_ref[...] = jnp.clip(q, -maxq - 1, maxq).astype(jnp.int8)
    s_ref[...] = scale[:, 0]


def quantize_nearest_kernel(x_ref, q_ref, s_ref, *, maxq):
    """Deterministic round-to-nearest-even body: no noise operand, so jitted
    serving steps can quantize KV writes without threading PRNG keys. The
    half-point bias nearest rounding introduces is irrelevant for KV storage
    (no gradient-unbiasedness requirement) and replay stays reproducible."""
    x = x_ref[...].astype(jnp.float32)               # (bm, block)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax == 0.0, 1.0, amax / maxq)
    q = jnp.round(x / scale)                         # ties to even
    q_ref[...] = jnp.clip(q, -maxq - 1, maxq).astype(jnp.int8)
    s_ref[...] = scale[:, 0]


def quantize_pallas(x, noise=None, *, bits=8, block_rows=256,
                    mode="stochastic", interpret=False):
    """x: (rows, block) f32; noise: same shape uniform[0,1) (stochastic mode
    only — nearest mode takes no noise). Returns (q int8 (rows, block),
    scales f32 (rows,))."""
    rows, block = x.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0
    maxq = float(2 ** (bits - 1) - 1)
    row_spec = pl.BlockSpec((block_rows, block), lambda i: (i, 0))
    out_specs = [row_spec, pl.BlockSpec((block_rows,), lambda i: (i,))]
    out_shape = [
        jax.ShapeDtypeStruct((rows, block), jnp.int8),
        jax.ShapeDtypeStruct((rows,), jnp.float32),
    ]
    if mode == "nearest":
        kern = functools.partial(quantize_nearest_kernel, maxq=maxq)
        return pl.pallas_call(
            kern, grid=(rows // block_rows,), in_specs=[row_spec],
            out_specs=out_specs, out_shape=out_shape, interpret=interpret)(x)
    if mode != "stochastic":
        raise ValueError(f"unknown quantize mode {mode!r}")
    if noise is None:
        raise ValueError("stochastic mode needs a noise operand")
    kern = functools.partial(quantize_kernel, maxq=maxq)
    return pl.pallas_call(
        kern,
        grid=(rows // block_rows,),
        in_specs=[row_spec, row_spec],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, noise)
