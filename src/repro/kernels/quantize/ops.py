"""jit'd wrappers: flat-gradient <-> (int8 blocks, scales)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quantize.kernel import quantize_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("bits", "block", "interpret"))
def quantize_blocks(flat, key, *, bits=8, block=256, interpret=None):
    """flat: (n,) f32 gradient; returns (q (rows, block) int8, scales (rows,),
    n) — padded to a block multiple."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    n = flat.shape[0]
    pad = (-n) % block
    x = jnp.pad(flat.astype(jnp.float32), (0, pad)).reshape(-1, block)
    rows = x.shape[0]
    block_rows = 256
    while rows % block_rows:           # largest power-of-two divisor ≤ 256
        block_rows //= 2
    noise = jax.random.uniform(key, x.shape)
    q, s = quantize_pallas(x, noise, bits=bits, block_rows=block_rows,
                           interpret=interpret)
    return q, s


@partial(jax.jit, static_argnames=("n",))
def dequantize_blocks(q, scales, n=None):
    flat = (q.astype(jnp.float32) * scales[:, None]).reshape(-1)
    return flat if n is None else flat[:n]
