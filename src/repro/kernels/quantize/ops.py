"""jit'd wrappers: flat-gradient <-> (int8 blocks, scales), plus the KV-cache
quantization primitives used by the paged serving pools."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quantize.kernel import quantize_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("bits", "block", "mode", "interpret"))
def quantize_blocks(flat, key=None, *, bits=8, block=256, mode="stochastic",
                    interpret=None):
    """flat: (n,) f32 gradient; returns (q (rows, block) int8, scales (rows,),
    n) — padded to a block multiple. mode="nearest" is deterministic (no key
    needed); "stochastic" keeps E[dequant(quant(g))] = g for gradients."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    n = flat.shape[0]
    pad = (-n) % block
    x = jnp.pad(flat.astype(jnp.float32), (0, pad)).reshape(-1, block)
    rows = x.shape[0]
    block_rows = 256
    while rows % block_rows:           # largest power-of-two divisor ≤ 256
        block_rows //= 2
    if mode == "nearest":
        noise = None
    else:
        if key is None:
            raise ValueError("stochastic mode needs a PRNG key")
        noise = jax.random.uniform(key, x.shape)
    q, s = quantize_pallas(x, noise, bits=bits, block_rows=block_rows,
                           mode=mode, interpret=interpret)
    return q, s


@partial(jax.jit, static_argnames=("n",))
def dequantize_blocks(q, scales, n=None):
    flat = (q.astype(jnp.float32) * scales[:, None]).reshape(-1)
    return flat if n is None else flat[:n]


@dataclasses.dataclass(frozen=True)
class KVQuantConfig:
    """Paged-KV pool quantization: int8 values + one f32 scale per
    (block-slot, kv-head) vector over head_dim. Hashable so it can live in
    frozen engine/provider configs and jit compile keys."""
    bits: int = 8

    def __post_init__(self):
        if self.bits != 8:
            raise ValueError(f"only int8 KV quantization supported, got bits={self.bits}")


def quantize_kv(x, *, bits=8):
    """x: (..., hd) f32 K or V vectors. Returns (q int8 same shape, scale f32
    (...,)) with one scale per vector — nearest-even rounding so every write
    path (prefill chunk, decode token, verify drafts, dense reference) stores
    bit-identical values for the same input vector."""
    maxq = float(2 ** (bits - 1) - 1)
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(amax == 0.0, 1.0, amax / maxq)
    q = jnp.round(xf / scale[..., None])
    return jnp.clip(q, -maxq - 1, maxq).astype(jnp.int8), scale


def dequantize_kv(q, scale):
    """Inverse of quantize_kv (up to rounding): (..., hd) int8 × (...,) f32."""
    return q.astype(jnp.float32) * scale[..., None]
