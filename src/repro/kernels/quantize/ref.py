"""Pure-jnp oracle for the block quantizer."""
import jax.numpy as jnp


def quantize_blocks_ref(x, noise=None, bits=8, mode="stochastic"):
    """x, noise: (rows, block); noise unused in nearest mode.
    Returns (q int8, scales f32)."""
    maxq = float(2 ** (bits - 1) - 1)
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax == 0.0, 1.0, amax / maxq)
    y = x / scale
    if mode == "nearest":
        q = jnp.round(y)
    else:
        lo = jnp.floor(y)
        q = lo + (noise < (y - lo)).astype(jnp.float32)
    return (jnp.clip(q, -maxq - 1, maxq).astype(jnp.int8), scale[:, 0])


def dequantize_blocks_ref(q, scales):
    return q.astype(jnp.float32) * scales[:, None]
