"""Weight update rules — survey Table 3, implemented exactly as printed.

Each rule is a pure (init_state, update) pair over arbitrary parameter
pytrees. Master weights and moments are f32 regardless of param dtype
(mixed-precision training; survey §6.3 quantization applies to *gradients*).

Table 3 rules:
  sgd        w ← w − η·g
  adaptive   w ← w − η_t·g                       (η_t decays)
  momentum   w ← w + μ(w − w_prev) − η·g          [Qian 1999]
  nesterov   v ← μv − η·∇ℓ(w + μv);  w ← w + v    [Nesterov 1983]
  adagrad    A += g²;  w ← w − η·g/√(A+ε)         [Duchi et al. 2011]
  rmsprop    A' = βA' + (1−β)g²;  w ← w − η·g/√(A'+ε)   [Hinton 2012]
  adam       m̂, v̂ bias-corrected first/second moments  [Kingma & Ba 2015]
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


def _f32(tree):
    return jax.tree.map(lambda p: p.astype(jnp.float32), tree)


def _zeros(tree):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), tree)


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


def make_optimizer(name: str, lr: float = 1e-3, *, momentum: float = 0.9,
                   beta1: float = 0.9, beta2: float = 0.999, eps: float = 1e-8,
                   decay_steps: int = 10_000, weight_decay: float = 0.0,
                   grad_clip: float = 0.0) -> Optimizer:
    """Build an update rule. `grad_clip` applies global-norm clipping
    (survey §3.2, gradient clipping for RNNs / async updates)."""

    def clip(grads):
        if not grad_clip:
            return grads
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / (gn + 1e-12))
        return jax.tree.map(lambda g: g * scale, grads)

    def finish(new_master, params, extra, step):
        new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), new_master, params)
        return new_params, {"master": new_master, "step": step + 1, **extra}

    # ------------------------------------------------------------------ rules
    if name == "sgd":
        def init(params):
            return {"master": _f32(params), "step": jnp.int32(0)}

        def update(grads, state, params):
            grads = clip(grads)
            new = jax.tree.map(lambda w, g: w - lr * g.astype(jnp.float32),
                               state["master"], grads)
            return finish(new, params, {}, state["step"])

    elif name == "adaptive":
        def init(params):
            return {"master": _f32(params), "step": jnp.int32(0)}

        def update(grads, state, params):
            grads = clip(grads)
            t = state["step"].astype(jnp.float32)
            lr_t = lr / (1.0 + t / decay_steps)
            new = jax.tree.map(lambda w, g: w - lr_t * g.astype(jnp.float32),
                               state["master"], grads)
            return finish(new, params, {}, state["step"])

    elif name == "momentum":
        def init(params):
            m = _f32(params)
            return {"master": m, "prev": m, "step": jnp.int32(0)}

        def update(grads, state, params):
            grads = clip(grads)
            new = jax.tree.map(
                lambda w, wp, g: w + momentum * (w - wp) - lr * g.astype(jnp.float32),
                state["master"], state["prev"], grads)
            return finish(new, params, {"prev": state["master"]}, state["step"])

    elif name == "nesterov":
        def init(params):
            return {"master": _f32(params), "vel": _zeros(params), "step": jnp.int32(0)}

        def update(grads, state, params):
            # caller evaluates grads at the lookahead point w + μv by reading
            # `lookahead(state)`; falls back to standard momentum on plain grads
            grads = clip(grads)
            vel = jax.tree.map(lambda v, g: momentum * v - lr * g.astype(jnp.float32),
                               state["vel"], grads)
            new = jax.tree.map(lambda w, v: w + v, state["master"], vel)
            return finish(new, params, {"vel": vel}, state["step"])

    elif name == "adagrad":
        def init(params):
            return {"master": _f32(params), "accum": _zeros(params), "step": jnp.int32(0)}

        def update(grads, state, params):
            grads = clip(grads)
            accum = jax.tree.map(lambda a, g: a + jnp.square(g.astype(jnp.float32)),
                                 state["accum"], grads)
            new = jax.tree.map(
                lambda w, g, a: w - lr * g.astype(jnp.float32) / jnp.sqrt(a + eps),
                state["master"], grads, accum)
            return finish(new, params, {"accum": accum}, state["step"])

    elif name == "rmsprop":
        def init(params):
            return {"master": _f32(params), "accum": _zeros(params), "step": jnp.int32(0)}

        def update(grads, state, params):
            grads = clip(grads)
            accum = jax.tree.map(
                lambda a, g: beta2 * a + (1 - beta2) * jnp.square(g.astype(jnp.float32)),
                state["accum"], grads)
            new = jax.tree.map(
                lambda w, g, a: w - lr * g.astype(jnp.float32) / (jnp.sqrt(a) + eps),
                state["master"], grads, accum)
            return finish(new, params, {"accum": accum}, state["step"])

    elif name == "adam":
        def init(params):
            return {"master": _f32(params), "m": _zeros(params), "v": _zeros(params),
                    "step": jnp.int32(0)}

        def update(grads, state, params):
            grads = clip(grads)
            t = state["step"].astype(jnp.float32) + 1.0
            m = jax.tree.map(lambda m_, g: beta1 * m_ + (1 - beta1) * g.astype(jnp.float32),
                             state["m"], grads)
            v = jax.tree.map(lambda v_, g: beta2 * v_ + (1 - beta2) * jnp.square(g.astype(jnp.float32)),
                             state["v"], grads)
            bc1 = 1.0 - beta1 ** t
            bc2 = 1.0 - beta2 ** t

            def upd(w, m_, v_):
                mh = m_ / bc1
                vh = v_ / bc2
                step = lr * mh / (jnp.sqrt(vh) + eps)
                if weight_decay:
                    step = step + lr * weight_decay * w
                return w - step

            new = jax.tree.map(upd, state["master"], m, v)
            return finish(new, params, {"m": m, "v": v}, state["step"])

    else:
        raise ValueError(f"unknown optimizer {name!r}")

    return Optimizer(name, init, update)


def lookahead(state, momentum=0.9):
    """Nesterov lookahead point w + μv (Table 3's ∇ℓ(w^(t) − μ·v_t, z))."""
    return jax.tree.map(lambda w, v: w + momentum * v, state["master"], state["vel"])


OPTIMIZERS = ("sgd", "adaptive", "momentum", "nesterov", "adagrad", "rmsprop", "adam")
