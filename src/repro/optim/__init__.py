from repro.optim.optimizers import (  # noqa: F401
    OPTIMIZERS, Optimizer, make_optimizer,
)
