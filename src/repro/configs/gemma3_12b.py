"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt family; 12B config] 48L d_model=3840 16H (GQA kv=8)
d_ff=15360 vocab=262144, head_dim=256, sliding window 1024 for local layers.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-12b",
    family="dense",
    source="hf:google/gemma-3-1b-pt (gemma-3 family card)",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262_144,
    attention_type="local_global",
    local_global_ratio=5,
    window_size=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
))
