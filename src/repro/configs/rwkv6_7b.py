"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=4096,
    num_heads=64,            # d_model / ssm_head_dim
    num_kv_heads=64,
    d_ff=14336,              # channel-mix hidden
    vocab_size=65_536,
    ssm_type="rwkv6",
    ssm_head_dim=64,
    rope_mode="none",
))
