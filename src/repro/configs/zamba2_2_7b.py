"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block. [arXiv:2411.15242]

54 layers modeled as 9 superblocks x (5 Mamba2 layers + 1 shared-weight
attention layer): the Zamba trick stores the attention block's parameters
once and reuses them at every superblock.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32_000,
    ssm_type="mamba2",
    ssm_state_dim=64,
    ssm_head_dim=64,
    hybrid_ssm_per_attn=5,
))
