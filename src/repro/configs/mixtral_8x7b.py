"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention. [arXiv:2401.04088]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    source="arXiv:2401.04088",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32_000,
    attention_type="sliding",
    window_size=4096,
    num_experts=8,
    experts_per_token=2,
))
