"""yi-9b [dense] — llama-arch GQA. [arXiv:2403.04652]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="yi-9b",
    family="dense",
    source="arXiv:2403.04652",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64_000,
))
