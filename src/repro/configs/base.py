"""Model/config registry for all assigned architectures.

Every architecture from the assignment pool is a `ModelConfig`; reduced
variants (for CPU smoke tests) are derived with `reduced()`. Input shapes
(the four assigned global shapes) live in `SHAPES`.
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                 # citation (paper / model card)
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: Optional[int] = None   # default d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 512
    # attention pattern
    attention_type: str = "full"     # full | sliding | local_global
    window_size: int = 4096
    local_global_ratio: int = 0      # N local layers per 1 global (gemma3: 5)
    rope_theta: float = 10_000.0
    rope_mode: str = "standard"      # standard | mrope | none
    # mixture of experts
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # state-space / recurrent
    ssm_type: str = ""               # "" | rwkv6 | mamba2
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    # hybrid (zamba2-style): superblock = N ssm layers + 1 shared attn layer
    hybrid_ssm_per_attn: int = 0
    # modality frontend stub: model consumes embeddings instead of token ids
    frontend: str = "none"           # none | vision_stub | audio_stub
    num_codebooks: int = 0           # musicgen
    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # training
    remat: bool = True
    loss_chunk: int = 512            # chunked cross-entropy block (big vocabs)
    attn_chunk: int = 1024           # chunked-attention query block (XLA path)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Whether the arch supports long-context decode (long_500k)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.attention_type in ("sliding", "local_global")
        )

    def param_count(self) -> int:
        """Analytical parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        qo = d * self.num_heads * hd * 2
        kv = d * self.num_kv_heads * hd * 2
        attn = qo + kv
        mlp_dense = 3 * d * self.d_ff  # SwiGLU: gate+in+out
        n = 0
        if self.family == "ssm" and self.ssm_type == "rwkv6":
            per_layer = 6 * d * d + 3 * d * self.d_ff  # r,k,v,g,w,o + channel-mix
            n += self.num_layers * per_layer
        elif self.family == "hybrid":
            nb = self.num_layers // (self.hybrid_ssm_per_attn + 1)
            mamba = self._mamba_params()
            n += nb * self.hybrid_ssm_per_attn * (mamba + mlp_dense)
            n += attn + mlp_dense  # shared attention block (stored once)
        else:
            per_layer = attn
            if self.num_experts:
                per_layer += self.num_experts * 3 * d * self.d_ff + d * self.num_experts
            else:
                per_layer += mlp_dense
            n += self.num_layers * per_layer
        n += self.num_layers * 2 * d  # norms
        n += self.vocab_size * d      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d  # head
        return n

    def _mamba_params(self) -> int:
        d = self.d_model
        d_inner = 2 * d
        return d * d_inner * 2 + d_inner * d + d_inner * (self.ssm_state_dim * 2 + 2)

    def active_param_count(self) -> int:
        """MoE: params active per token (for MODEL_FLOPS = 6·N_active·D)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        all_experts = self.num_layers * self.num_experts * 3 * d * self.d_ff
        active = self.num_layers * self.experts_per_token * 3 * d * self.d_ff
        return total - all_experts + active


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict[str, ModelConfig] = {}

ARCH_MODULES = [
    "gemma3_12b", "phi4_mini_3_8b", "qwen2_vl_2b", "mixtral_8x7b",
    "stablelm_3b", "rwkv6_7b", "yi_9b", "qwen3_moe_30b_a3b",
    "zamba2_2_7b", "musicgen_medium",
]


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    key = name.replace("-", "_").replace(".", "_")
    for k, v in _REGISTRY.items():
        if k == name or k.replace("-", "_").replace(".", "_") == key:
            return v
    raise KeyError(f"unknown architecture {name!r}; known: {sorted(_REGISTRY)}")


def list_configs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    for mod in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 256,
            vocab: int = 512, seq_ok: bool = True) -> ModelConfig:
    """Reduced same-family variant: ≤2 layers, d_model ≤ 512, ≤4 experts."""
    num_heads = 4
    head_dim = 64
    num_kv = min(cfg.num_kv_heads, num_heads)
    if cfg.num_kv_heads >= cfg.num_heads:
        num_kv = num_heads           # MHA-style archs stay MHA
    elif cfg.num_kv_heads * 2 >= cfg.num_heads:
        num_kv = 2
    else:
        num_kv = 1
    kw = dict(
        name=cfg.name + "-reduced",
        num_layers=layers,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        d_ff=d_model * 2,
        vocab_size=vocab,
        window_size=min(cfg.window_size, 32),
        loss_chunk=64,
        attn_chunk=32,
        remat=False,
    )
    if cfg.num_experts:
        kw["num_experts"] = 4
        kw["experts_per_token"] = min(cfg.experts_per_token, 2)
    if cfg.attention_type == "local_global":
        kw["local_global_ratio"] = 1
        kw["num_layers"] = 2         # 1 superblock: 1 local + 1 global
    if cfg.family == "hybrid":
        kw["hybrid_ssm_per_attn"] = 1
        kw["num_layers"] = 2         # 1 superblock: 1 mamba + shared attn
        kw["ssm_state_dim"] = min(cfg.ssm_state_dim or 16, 16)
    if cfg.ssm_type == "rwkv6":
        kw["ssm_head_dim"] = 32
    return replace(cfg, **kw)
