"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution. [arXiv:2409.12191]

Transformer backbone only; the ViT vision encoder + projector is a stub:
`input_specs()` supplies precomputed patch embeddings (B, S, D) and 3-D
M-RoPE position ids (3, B, S).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    source="arXiv:2409.12191",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151_936,
    rope_mode="mrope",
    frontend="vision_stub",
    tie_embeddings=True,
))
