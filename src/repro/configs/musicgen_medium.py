"""musicgen-medium [audio] — decoder-only over EnCodec tokens. [arXiv:2306.05284]

Transformer backbone only; the EnCodec conv codec is a stub: `input_specs()`
supplies precomputed frame embeddings (sum of the 4 codebook embeddings).
vocab=2048 per codebook; the delay interleave pattern is out of scope.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    family="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    frontend="audio_stub",
    num_codebooks=4,
))
