"""qwen3-moe-30b-a3b [moe] — 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,                # per-expert intermediate
    vocab_size=151_936,
    num_experts=128,
    experts_per_token=8,
))
