"""stablelm-3b [dense] — MHA-style (kv=heads). [hf:stabilityai/stablelm-2-1_6b family]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-3b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b (stablelm family card)",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50_304,
))
