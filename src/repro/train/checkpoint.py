"""Checkpointing: pytree <-> .npz with path-encoded keys (survey §6.2:
"the simplest form of fault tolerance in machine learning is
checkpoint/restart"). Host-gathered; restore re-shards via device_put."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "|"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arr = arr.astype(np.float32)   # npz-portable; bf16→f32 lossless
        out[key] = arr
    return out


def save(path: str, tree, step: int = 0) -> str:
    arrays = _flatten(tree)
    arrays["__step__"] = np.asarray(step)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)
    return path


def restore(path: str, template, shardings=None):
    """Restore into `template`'s structure; optionally device_put with
    shardings (same structure)."""
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    step = int(arrays.pop("__step__", 0))
    flat = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path_keys, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path_keys)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = jnp.asarray(arrays[key], dtype=leaf.dtype)
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree, step
