"""Training step builders.

Two runtimes (DESIGN.md §3):

* **pjit mode** — `make_train_step`: XLA-partitioned via the ShardingPlan's
  in/out shardings; collectives are implicit. Used by the launcher and all
  dry-runs.
* **paper mode** — `make_paper_train_step`: data-parallel `shard_map` where
  the gradient allreduce is *explicit* — our own ring/tree/butterfly/
  Rabenseifner schedule (survey §2.5) with optional gradient compression +
  error feedback (survey §6.3). This is the survey's distributed-SGD
  pipeline made concrete.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import parallelism as par
from repro.models import transformer as T


# ------------------------------------------------------------------ state
def init_state(cfg, optimizer, key):
    params = T.init_params(cfg, key)
    return {"params": params, "opt": optimizer.init(params)}


def abstract_state(cfg, optimizer):
    return jax.eval_shape(lambda k: init_state(cfg, optimizer, k),
                          jax.random.PRNGKey(0))


def state_shardings(state, plan):
    """NamedShardings for a TrainState pytree (params + optimizer)."""
    params = state["params"]
    p_specs = plan.param_specs(params)
    o_specs = plan.opt_specs(params)
    params_treedef = jax.tree_util.tree_structure(params)

    def opt_entry(v):
        if jax.tree_util.tree_structure(v) == params_treedef:
            return o_specs
        return jax.tree.map(lambda _: P(), v)

    opt = state["opt"]
    opt_specs = {k: opt_entry(v) for k, v in opt.items()}
    specs = {"params": p_specs, "opt": opt_specs}
    return jax.tree.map(lambda s: NamedSharding(plan.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# -------------------------------------------------------------- pjit mode
def make_train_step(cfg, optimizer, plan, *, donate=True, accum_steps=1):
    """train_step(state, batch). With accum_steps > 1 the global batch is
    split into microbatches scanned sequentially with f32 gradient
    accumulation — activation live-range shrinks ~accum_steps× at the cost
    of accum_steps× more (smaller) collectives (§Perf: the lever that fits
    gemma3-12b train_4k into v5e HBM)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, batch))(params)

    def train_step(state, batch):
        with par.plan_context(plan):
            if accum_steps == 1:
                loss, grads = grads_of(state["params"], batch)
            else:
                def split(a):
                    return a.reshape((accum_steps, a.shape[0] // accum_steps)
                                     + a.shape[1:])

                micro = {k: split(v) for k, v in batch.items()
                         if k != "positions"}
                if "positions" in batch:   # mrope (3, B, S): split on axis 1
                    p = batch["positions"]
                    micro["positions"] = p.reshape(
                        (3, accum_steps, p.shape[1] // accum_steps) + p.shape[2:]
                    ).swapaxes(0, 1)

                def micro_step(acc, mb):
                    loss_i, g_i = grads_of(state["params"], mb)
                    acc_loss, acc_g = acc
                    acc_g = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32), acc_g, g_i)
                    return (acc_loss + loss_i, acc_g), None

                zero = (jnp.float32(0.0),
                        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                     state["params"]))
                (loss, grads), _ = jax.lax.scan(micro_step, zero, micro)
                loss = loss / accum_steps
                grads = jax.tree.map(lambda g: g / accum_steps, grads)
        new_params, new_opt = optimizer.update(grads, state["opt"], state["params"])
        metrics = {"loss": loss}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def jit_train_step(cfg, optimizer, plan, state_abs, batch_abs):
    """jit with explicit in/out shardings (the production entry point)."""
    step = make_train_step(cfg, optimizer, plan)
    st_sh = state_shardings(state_abs, plan)
    b_sh = plan.batch_shardings(batch_abs)
    rep = NamedSharding(plan.mesh, P())
    return jax.jit(
        step,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, {"loss": rep}),
        donate_argnums=(0,),
    )


# ------------------------------------------------------------- paper mode
def make_paper_train_step(cfg, optimizer, mesh, *, axis="data",
                          algorithm="ring", compression=None):
    """Explicit data-parallel SGD over `axis` via shard_map (survey §5.1+§6.3).

    Per-shard gradients are reduced with `core.collectives` (algorithm =
    ring|tree|butterfly|rabenseifner|psum), optionally compressed with error
    feedback (`compression` = a core.compression.Compressor). The error-
    feedback residual is carried in the state (survey: "local gradient
    accumulation", Seide et al. / Lin et al.).
    """
    from repro.core.compat import shard_map
    from repro.core import collectives as coll

    def local_grads(params, batch):
        return jax.value_and_grad(lambda p: T.loss_fn(cfg, p, batch))(params)

    def step(state, batch, residual):
        loss, grads = local_grads(state["params"], batch)

        if compression is not None:
            grads, residual = compression.compress_with_feedback(grads, residual)

        grads = jax.tree.map(
            lambda g: coll.allreduce_mean(g, axis, algorithm=algorithm), grads)
        loss = coll.allreduce_mean(loss, axis, algorithm="psum")

        new_params, new_opt = optimizer.update(grads, state["opt"], state["params"])
        return {"params": new_params, "opt": new_opt}, {"loss": loss}, residual

    pspec_state = jax.tree.map(lambda _: P(), {"dummy": 0})  # built below

    def wrapped(state, batch, residual):
        in_specs = (
            jax.tree.map(lambda _: P(), state),
            jax.tree.map(lambda _: P(axis), batch),
            jax.tree.map(lambda _: P(), residual),
        )
        out_specs = (
            jax.tree.map(lambda _: P(), state),
            {"loss": P()},
            jax.tree.map(lambda _: P(), residual),
        )
        f = shard_map(step, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        return f(state, batch, residual)

    return wrapped


def zero_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
