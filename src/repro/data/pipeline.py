"""Deterministic synthetic data pipeline.

Two sources:
  * `SyntheticLM` — a seeded Markov-ish token stream with learnable structure
    (each token is a noisy function of the previous ones), so small models
    show a *decreasing* loss curve — needed to validate convergence claims
    (minibatch effect Fig 7, compression §6.3, staleness §6.1).
  * `copy_task`   — sequence copy; sanity-checkable exactly.

Batches are `{"tokens": (B, S) int32, "labels": (B, S) int32}`, labels being
the next-token shift. Iteration is epoch-based with per-epoch shuffling
(survey §2.1: "shuffling the dataset S before the loop").
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    """Order-2 synthetic language: t_{i+1} = (a·t_i + b·t_{i-1} + noise) mod V."""

    def __init__(self, vocab_size: int, seq_len: int, *, seed: int = 0,
                 noise: float = 0.1, num_docs: int = 4096):
        self.vocab = vocab_size
        self.seq = seq_len
        self.noise = noise
        self.num_docs = num_docs
        self.rng = np.random.default_rng(seed)
        self.a, self.b = 31, 17

    def _doc(self, rng):
        t = np.empty(self.seq + 1, np.int64)
        t[0] = rng.integers(self.vocab)
        t[1] = rng.integers(self.vocab)
        for i in range(1, self.seq):
            nxt = (self.a * t[i] + self.b * t[i - 1]) % self.vocab
            if rng.random() < self.noise:
                nxt = rng.integers(self.vocab)
            t[i + 1] = nxt
        return t

    def batches(self, batch_size: int, steps: int):
        """Yield `steps` batches deterministically."""
        for s in range(steps):
            rng = np.random.default_rng((hash(("batch", s)) & 0xFFFFFFFF))
            docs = np.stack([self._doc(rng) for _ in range(batch_size)])
            yield {
                "tokens": docs[:, :-1].astype(np.int32),
                "labels": docs[:, 1:].astype(np.int32),
            }


def copy_task(batch_size: int, seq_len: int, vocab: int, seed: int = 0):
    """tokens = [pattern, pattern]; labels shifted — learnable by one layer."""
    rng = np.random.default_rng(seed)
    half = seq_len // 2
    pat = rng.integers(1, vocab, (batch_size, half))
    tokens = np.concatenate([pat, pat], axis=1).astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    return {"tokens": tokens, "labels": labels}


def shard_batch(batch, plan):
    """Device-put a host batch with the plan's batch shardings."""
    import jax
    shardings = plan.batch_shardings(batch)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), batch, shardings)
