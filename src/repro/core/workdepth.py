"""Work-Depth concurrency model — survey §2.5, Tables 4 & 6, §3.3.1.

W = total operations (vertices of the computation DAG), D = longest
dependency path. Average parallelism = W/D. Formulas follow the paper's
appendix conventions:

  conv(H, K, C_in, C_out):  W = H'·W'·C_out·(C_in·K_x·K_y)  multiply-adds...
  The paper's §3.3.1 LeNet numbers imply per-output-pixel work
  C_in·K²·C_out counted as fused multiply-accumulate "operations", and
  D = ⌈log2 C_in⌉ + ⌈log2 K_x⌉ + ⌈log2 K_y⌉ per layer. We reproduce the
  published W = 665,832 / D = 41 for LeNet-5 inference exactly (test-pinned).
"""
from __future__ import annotations

import math
from dataclasses import dataclass


def _clog2(x):
    return int(math.ceil(math.log2(x))) if x > 1 else 0


@dataclass(frozen=True)
class WD:
    work: int
    depth: int

    @property
    def avg_parallelism(self) -> float:
        return self.work / max(self.depth, 1)

    def __add__(self, other: "WD") -> "WD":
        return WD(self.work + other.work, self.depth + other.depth)


# ------------------------------------------------------------------- Table 4
def fully_connected(N, C_in, C_out, phase="y") -> WD:
    w = N * C_in * C_out
    d = {"y": _clog2(C_in), "dw": _clog2(N), "dx": _clog2(C_out)}[phase]
    return WD(w, d)


def conv_direct(N, H, W_, C_in, C_out, Kx, Ky, phase="y") -> WD:
    Hp, Wp = H - Ky + 1, W_ - Kx + 1
    if phase == "dx":
        Hp, Wp = H, W_
    work = N * C_out * C_in * Hp * Wp * Kx * Ky
    depth = _clog2(Kx) + _clog2(Ky) + _clog2(C_in)
    return WD(work, depth)


def pooling(N, C, H, W_, Kx, Ky, phase="y") -> WD:
    if phase == "y":
        return WD(N * C * H * W_, _clog2(Kx) + _clog2(Ky))
    return WD(N * C * H * W_, 0)   # dx: O(1)


def activation(N, C, H, W_, phase="y") -> WD:
    return WD(N * C * H * W_, 0)   # O(1) depth


def batchnorm(N, C, H, W_, phase="y") -> WD:
    return WD(N * C * H * W_, _clog2(N))


def attention(N, S, H, hd, phase="y", window=None) -> WD:
    """GQA/MHA self-attention (beyond-paper extension of Table 4): scores +
    weighted sum. Sub-quadratic with a sliding window."""
    span = min(S, window) if window else S
    work = 2 * N * H * S * span * hd + N * H * S * span  # qk^T, softmax, pv
    depth = _clog2(hd) + _clog2(span) + 4
    return WD(work, depth)


# ------------------------------------------------------------------- Table 6
def conv_im2col(N, H, W_, C_in, C_out, Kx, Ky) -> WD:
    return conv_direct(N, H, W_, C_in, C_out, Kx, Ky)   # same W and D


def conv_fft(N, H, W_, C_in, C_out, Kx=None, Ky=None, c=5.0) -> WD:
    hw = H * W_
    work = int(c * hw * math.log2(hw) * (C_out * C_in + N * C_in + N * C_out)
               + hw * N * C_in * C_out)
    depth = 2 * _clog2(hw) + _clog2(C_in)
    return WD(work, depth)


def conv_winograd(N, H, W_, C_in, C_out, r, m) -> WD:
    """m×m tiles, r×r kernels (Table 6's α ≡ m − r + 1 … published formula)."""
    alpha = m - r + 1
    Ptiles = N * math.ceil(H / m) * math.ceil(W_ / m)
    work = int(alpha * (r ** 2 + alpha * r + 2 * alpha ** 2 + alpha * m + m ** 2)
               + C_out * C_in * Ptiles)
    depth = 2 * _clog2(r) + 4 * _clog2(alpha) + _clog2(C_in)
    return WD(work, depth)


# -------------------------------------------------------- §3.3.1 case studies
def lenet5_layers() -> dict[str, WD]:
    """Per-layer W-D for the paper's §3.3.1 LeNet-5 worked example, using the
    accounting that reproduces the published numbers:

      conv:  W = H_count²·C_out·C_in·K²      (paper uses the *output* size 28
             for conv1 but the *input* size 14 for conv2 — an internal
             inconsistency of the survey; we match it as printed and flag it
             in benchmarks/table5_networks.py)
             D = ⌈log2(C_in·K²)⌉ for conv1, but
             D = ⌈log2 Kx⌉+⌈log2 Ky⌉+⌈log2 C_in⌉ for conv2 (Table 6 form).
      pool:  W = 3·C·H_in² (3 ops per input element), D = 2·⌈log2 K⌉
      fc:    W = C_in·C_out, D = ⌈log2 C_in⌉   (matches Table 4 exactly)
    """
    return {
        "conv1": WD(28 * 28 * 6 * (1 * 5 * 5), _clog2(1 * 5 * 5)),        # 117600, 5
        "pool1": WD(3 * 6 * 28 * 28, _clog2(2) + _clog2(2)),              # 14112, 2
        "conv2": WD(14 * 14 * 16 * (6 * 5 * 5), _clog2(5) + _clog2(5) + _clog2(6)),  # 470400, 9
        "pool2": WD(3 * 16 * 10 * 10, _clog2(2) + _clog2(2)),             # 4800, 2
        "fc1": WD(400 * 120, _clog2(400)),                                # 48000, 9
        "fc2": WD(120 * 84, _clog2(120)),                                 # 10080, 7
        "fc3": WD(84 * 10, _clog2(84)),                                   # 840, 7
    }


def lenet5_inference() -> WD:
    """Reproduces the paper's published totals: W = 665,832, D = 41."""
    total = WD(0, 0)
    for wd in lenet5_layers().values():
        total += wd
    return total


# published per-layer numbers (used for the pinned test + Table 5 benchmark)
LENET5_PAPER = {
    "conv1": (117_600, 5),
    "pool1": (14_112, 2),
    "conv2": (470_400, 9),
    "pool2": (4_800, 2),
    "fc1": (48_000, 9),
    "fc2": (10_080, 7),
    "fc3": (840, 7),
    "total": (665_832, 41),
}


def lenet5_paper_total() -> WD:
    w = sum(v[0] for k, v in LENET5_PAPER.items() if k != "total")
    d = sum(v[1] for k, v in LENET5_PAPER.items() if k != "total")
    return WD(w, d)


# --------------------------------------------------------- Table 5 networks
def network_table5():
    """Table 5: published parameter/operation counts for the five networks."""
    return {
        "LeNet": {"params": 60e3, "layers": 7, "ops": None},
        "AlexNet": {"params": 61e6, "layers": 13, "ops": 725e6},
        "GoogLeNet": {"params": 6.8e6, "layers": 27, "ops": 1566e6},
        "ResNet": {"params": (1.7e6, 60.2e6), "layers": (50, 152), "ops": (1000e6, 2300e6)},
        "DenseNet": {"params": (15.3e6, 30e6), "layers": (40, 250), "ops": (600e6, 1130e6)},
    }


# ------------------------------------------------------ transformer extension
def transformer_train_wd(cfg, batch, seq) -> WD:
    """Whole-decoder W-D for one training step (fwd+bwd ≈ 3× fwd work,
    +⌈log2 N·S⌉ gradient-reduction depth) — our beyond-paper extension of the
    paper's per-network analysis to the assigned architectures."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    total = WD(0, 0)
    for _ in range(1):  # per layer, multiplied below
        pass
    per_layer = WD(0, 0)
    if cfg.family == "ssm":
        per_layer += WD(batch * seq * 6 * d * d, _clog2(d))       # projections
        per_layer += WD(batch * seq * d * cfg.ssm_head_dim, _clog2(cfg.ssm_head_dim) + seq // max(seq, 1))
        per_layer += WD(batch * seq * 3 * d * cfg.d_ff, _clog2(d))
    else:
        window = cfg.window_size if cfg.attention_type == "sliding" else None
        h = cfg.num_heads
        per_layer += WD(batch * seq * 2 * d * (cfg.num_heads + cfg.num_kv_heads) * hd,
                        _clog2(d))
        per_layer += attention(batch, seq, h, hd, window=window)
        ff = cfg.d_ff * (cfg.experts_per_token or 1)
        per_layer += WD(batch * seq * 3 * d * ff, _clog2(d))
    total = WD(per_layer.work * cfg.num_layers * 3,               # fwd+bwd
               per_layer.depth * cfg.num_layers * 2)
    total += WD(batch * seq * d * cfg.vocab_size * 3, _clog2(d) + _clog2(cfg.vocab_size))
    total += WD(0, _clog2(batch * seq))                           # grad reduce
    return total
