"""Parallelism strategies (survey §5) as composable sharding plans.

A `ShardingPlan` maps parameter / activation / cache pytrees to
`PartitionSpec`s over a mesh with axes drawn from ('pod', 'data', 'model').

Presets (selectable via ``--plan``):
  dp           pure data parallelism (§5.1): params replicated, batch sharded
               over every mesh axis (the 2018 default — all devices are data).
  tp           pure model parallelism (§5.2): heads / FFN / experts / vocab
               sharded over *all* axes; batch replicated.
  dp_tp        hybrid (§5.4, Krizhevsky "one weird trick"): batch over
               ('pod','data'), tensor dims over 'model'.  Paper-faithful
               baseline for every dry-run.
  dp_tp_zero1  beyond-paper: dp_tp + optimizer state sharded over 'data'
               (reduce-scatter descendant of the sharded parameter server §6.2).
  dp_tp_seq    beyond-paper: dp_tp + sequence(context) sharding of long KV
               caches/activations over 'data' for decode shapes.

Activation constraints inside model code go through `constrain(x, names)`,
a no-op unless a plan context is active (keeps models import-clean).
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PLANS = ("dp", "tp", "dp_tp", "dp_tp_zero1", "dp_tp_seq", "dp_tp_sp", "dp_tp_sp_zero1")

_ctx: contextvars.ContextVar = contextvars.ContextVar("sharding_ctx", default=None)


def _divisible(n: Optional[int], axes: tuple[str, ...], mesh: Mesh) -> bool:
    if n is None:
        return False
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return n % size == 0 and n >= size


@dataclass
class ShardingPlan:
    name: str
    mesh: Mesh

    @property
    def batch_axes(self) -> tuple[str, ...]:
        ax = tuple(a for a in ("pod", "data") if a in self.mesh.shape)
        if self.name == "dp":           # every axis is a data axis
            return ax + (("model",) if "model" in self.mesh.shape else ())
        if self.name == "tp":
            return ()
        return ax

    @property
    def tensor_axes(self) -> tuple[str, ...]:
        if self.name == "dp":
            return ()
        if self.name == "tp":
            return tuple(a for a in ("pod", "data", "model") if a in self.mesh.shape)
        return ("model",)

    @property
    def seq_axes(self) -> tuple[str, ...]:
        """Axis for *activation/cache sequence* sharding: 'data' for the
        long-context decode plan; 'model' for Megatron-style sequence
        parallelism (residual stream sharded between layers, §Perf)."""
        if self.name == "dp_tp_seq":
            return ("data",)
        if self.name in ("dp_tp_sp", "dp_tp_sp_zero1"):
            return ("model",)
        return ()

    # ---------------------------------------------------------------- helpers
    def _shard_dim(self, size):
        """tensor axes if divisible, else nothing."""
        return self.tensor_axes if _divisible(size, self.tensor_axes, self.mesh) else None

    def spec_for_param(self, path: str, shape) -> P:
        """Name+shape-based tensor-parallel rules (§5.2: partition neurons)."""
        t = self.tensor_axes
        if not t:
            return P()
        dims = list(shape)
        # stacked leading superblock/inner-layer dims are never sharded
        def dspec(i):
            return self._shard_dim(dims[i])

        if re.search(r"embed/table|lm_head/w", path):
            # shard vocab dim: table (V, D) dim0; head w (D, V) dim1
            vdim = 0 if "table" in path else 1
            spec = [None] * len(dims)
            spec[vdim] = dspec(vdim)
            return P(*spec)
        if re.search(r"attn/(wq|wk|wv)", path):
            spec = [None] * len(dims)
            spec[-1] = dspec(len(dims) - 1)     # heads*hd output dim
            return P(*spec)
        if re.search(r"attn/wo", path):
            spec = [None] * len(dims)
            spec[-2] = dspec(len(dims) - 2)     # heads*hd input dim
            return P(*spec)
        if re.search(r"(mlp|cm_k)/(w_gate|w_in)|cm_k", path):
            spec = [None] * len(dims)
            spec[-1] = dspec(len(dims) - 1)     # ffn dim
            return P(*spec)
        if re.search(r"(mlp/w_out|cm_v)", path):
            spec = [None] * len(dims)
            spec[-2] = dspec(len(dims) - 2)
            return P(*spec)
        if re.search(r"moe/(w_gate|w_in|w_out)", path):
            # (..., E, D, F) / (..., E, F, D): expert dim if divisible, else F
            e_dim, f_dim = len(dims) - 3, (len(dims) - 1 if "out" not in path else len(dims) - 2)
            if "w_out" in path:
                f_dim = len(dims) - 2
            spec = [None] * len(dims)
            if _divisible(dims[e_dim], t, self.mesh):
                spec[e_dim] = t
            else:
                spec[f_dim] = dspec(f_dim)
            return P(*spec)
        if re.search(r"rwkv/(wr|wk|wv|wg|wo)|mamba/(in_proj|out_proj)", path):
            spec = [None] * len(dims)
            spec[-1] = dspec(len(dims) - 1)
            return P(*spec)
        return P()  # norms, biases, routers, small decays: replicated

    def spec_for_batch_leaf(self, path: str, shape) -> P:
        """Input batch: tokens/labels (B, S), embeds (B, S, D), mrope (3, B, S)."""
        b = self.batch_axes
        bspec = b if _divisible(shape[0], b, self.mesh) else None
        if path.endswith("positions") and len(shape) == 3 and shape[0] == 3:
            b2 = b if _divisible(shape[1], b, self.mesh) else None
            return P(None, b2, None)
        return P(bspec, *([None] * (len(shape) - 1)))

    def spec_for_cache_leaf(self, path: str, shape) -> P:
        """KV caches (n_sb, B, S, Hkv, hd) and SSM states (n_sb, B, H, ...)."""
        b = self.batch_axes
        spec = [None] * len(shape)
        if len(shape) >= 2 and _divisible(shape[1], b, self.mesh):
            spec[1] = b            # batch dim
        elif "/k" in path or "/v" in path:
            # batch unshardable (long_500k b=1): shard cache sequence instead
            seq_ax = self.seq_axes or (("data",) if "data" in self.mesh.shape
                                       and self.name not in ("dp", "tp") else ())
            if len(shape) >= 3 and seq_ax and _divisible(shape[2], seq_ax, self.mesh):
                spec[2] = seq_ax
        # shard kv heads / state heads over tensor axes when divisible
        if len(shape) >= 4 and self.tensor_axes:
            hdim = 3 if ("/k" in path or "/v" in path) else 2
            if hdim < len(shape) and _divisible(shape[hdim], self.tensor_axes, self.mesh):
                spec[hdim] = self.tensor_axes
        return P(*spec)

    # ------------------------------------------------------------- tree specs
    def tree_specs(self, tree, leaf_fn):
        paths = jax.tree_util.tree_flatten_with_path(tree)[0]
        flat = [leaf_fn("/".join(str(getattr(k, "key", k)) for k in path), leaf.shape)
                for path, leaf in paths]
        return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(tree), flat)

    def param_specs(self, params):
        return self.tree_specs(params, self.spec_for_param)

    def param_shardings(self, params):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.param_specs(params))

    def batch_specs(self, batch):
        return self.tree_specs(batch, self.spec_for_batch_leaf)

    def batch_shardings(self, batch):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.batch_specs(batch))

    def cache_specs(self, cache):
        return self.tree_specs(cache, self.spec_for_cache_leaf)

    def cache_shardings(self, cache):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.cache_specs(cache))

    def opt_specs(self, params, zero1: Optional[bool] = None):
        """Optimizer-moment specs; ZeRO-1 additionally shards the largest
        still-unsharded divisible dim over the data axis (sharded-PS, §6.2)."""
        zero1 = self.name in ("dp_tp_zero1", "dp_tp_sp_zero1") if zero1 is None else zero1
        base = self.param_specs(params)
        if not zero1 or "data" not in self.mesh.shape:
            return base

        def upgrade(path, leaf, spec):
            spec = list(spec) + [None] * (len(leaf.shape) - len(spec))
            order = sorted(range(len(leaf.shape)), key=lambda i: -leaf.shape[i])
            for i in order:
                if spec[i] is None and _divisible(leaf.shape[i], ("data",), self.mesh):
                    spec[i] = "data"
                    break
            return P(*spec)

        paths = jax.tree_util.tree_flatten_with_path(params)[0]
        flat_specs = jax.tree_util.tree_leaves(base, is_leaf=lambda x: isinstance(x, P))
        out = [upgrade(p, l, s) for (p, l), s in zip(
            [(path, leaf) for path, leaf in paths], flat_specs)]
        return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(params), out)

    # --------------------------------------------------- activation constraints
    def logical_spec(self, names) -> P:
        out = []
        for n in names:
            if n == "batch":
                out.append(self.batch_axes or None)
            elif n == "seq":
                out.append(self.seq_axes or None)
            elif n in ("heads", "ffn", "vocab", "expert"):
                out.append(self.tensor_axes or None)
            elif n == "capacity":
                out.append(self.batch_axes or None)
            else:
                out.append(None)
        return P(*out)


def make_plan(name: str, mesh: Mesh) -> ShardingPlan:
    if name not in PLANS:
        raise ValueError(f"unknown plan {name!r}; options: {PLANS}")
    return ShardingPlan(name, mesh)


# ------------------------------------------------------------------- context
@contextlib.contextmanager
def plan_context(plan: ShardingPlan):
    token = _ctx.set(plan)
    try:
        yield
    finally:
        _ctx.reset(token)


def current_plan():
    """The active ShardingPlan, or None outside a plan context."""
    return _ctx.get()


def constrain(x, names):
    """Apply a logical sharding constraint if a plan context is active."""
    plan = _ctx.get()
    if plan is None or not hasattr(x, "ndim"):
        return x
    names = tuple(names)
    if len(names) < x.ndim:            # scan/vmap may add leading dims
        names = (None,) * (x.ndim - len(names)) + names
    elif len(names) > x.ndim:
        names = names[-x.ndim:]
    raw = plan.logical_spec(names)
    # drop axes whose size doesn't divide the dim (GSPMD would pad; avoid)
    clean = []
    for dim, entry in zip(x.shape, tuple(raw) + (None,) * (x.ndim - len(raw))):
        if entry is None:
            clean.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        clean.append(axes if _divisible(dim, axes, plan.mesh) else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(plan.mesh, P(*clean)))
