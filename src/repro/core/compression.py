"""Gradient/parameter compression — survey §6.3, quantization + sparsification.

Quantizers (§6.3.1):
  stochastic_round_bf16   reduced floating precision w/ expectation-preserving
                          rounding [Gupta et al. 2015]
  int8 / int4 (QSGD)      multi-level stochastic quantization with per-block
                          scales [Alistarh et al. 2017]
  ternary                 {−1, 0, +1}·scale [TernGrad, Wen et al. 2017]
  onebit                  sign + per-tensor mean magnitude [Seide et al. 2014]

Sparsifiers (§6.3.2):
  topk                    relative threshold (top-k%) [Aji & Heafield 2017]
  threshold               static absolute threshold [Strom 2015]

All compressors support **error feedback** ("local gradient accumulation" —
the survey's key convergence condition for lossy compression): the residual
`g − decompress(compress(g + r))` is carried to the next step. DGC momentum
correction [Lin et al. 2018] is provided as a wrapper.

Every compressor reports its compression ratio analytically
(`ratio(shape)`), reproducing the survey's 846–2871× figures for
threshold+quantization pipelines.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------------ primitives
def stochastic_round(x, key, target=jnp.bfloat16):
    """Round x (f32) to `target` such that E[round(x)] = x [Gupta et al. 2015]."""
    x = x.astype(jnp.float32)
    down = x.astype(target)
    down_f = down.astype(jnp.float32)
    up = jnp.where(x >= down_f, _next_after(down, +1), _next_after(down, -1))
    up_f = up.astype(jnp.float32)
    denom = jnp.where(up_f == down_f, 1.0, up_f - down_f)
    p_up = jnp.clip((x - down_f) / denom, 0.0, 1.0)
    u = jax.random.uniform(key, x.shape)
    return jnp.where(u < p_up, up, down)


def _next_after(x, direction):
    """Next representable value of x (same dtype) toward ±inf."""
    bits = jax.lax.bitcast_convert_type(x, jnp.uint16 if x.dtype == jnp.bfloat16
                                        else jnp.uint32)
    one = jnp.ones_like(bits)
    pos_step = jnp.where(jax.lax.convert_element_type(x, jnp.float32) >= 0, one, -one)
    step = jnp.where(direction > 0, pos_step, -pos_step)
    return jax.lax.bitcast_convert_type(bits + step, x.dtype)


def quantize_int(x, key, bits=8, block=256):
    """QSGD-style per-block scaled stochastic integer quantization.
    Returns (q int8, scales f32, shape)."""
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    maxq = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / maxq
    scale = jnp.where(scale == 0, 1.0, scale)
    y = blocks / scale
    lo = jnp.floor(y)
    p = y - lo
    u = jax.random.uniform(key, y.shape)
    q = lo + (u < p)
    q = jnp.clip(q, -maxq - 1, maxq).astype(jnp.int8)
    return q, scale[:, 0], shape


def dequantize_int(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = int(np.prod(shape))
    return flat[:n].reshape(shape)


def ternarize(x, key):
    """TernGrad: g → s·sign(g)·b, b ~ Bernoulli(|g|/s), s = max|g|."""
    x = x.astype(jnp.float32)
    s = jnp.max(jnp.abs(x))
    s = jnp.where(s == 0, 1.0, s)
    p = jnp.abs(x) / s
    u = jax.random.uniform(key, x.shape)
    return s * jnp.sign(x) * (u < p)


def onebit(x):
    """1-bit SGD: sign(g) scaled by mean |g| per tensor [Seide et al. 2014]."""
    x = x.astype(jnp.float32)
    scale = jnp.mean(jnp.abs(x))
    return jnp.sign(x) * scale


def topk_sparsify(x, frac):
    """Keep top-`frac` fraction by |value|; returns dense masked tensor
    (indices+values transport is modeled analytically in ratio())."""
    x = x.astype(jnp.float32)
    flat = x.reshape(-1)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


def threshold_sparsify(x, tau):
    x = x.astype(jnp.float32)
    return jnp.where(jnp.abs(x) >= tau, x, 0.0)


# ------------------------------------------------------------------ Compressor
@dataclass(frozen=True)
class Compressor:
    """compress: (g f32, key) -> g̃ f32 (lossy round-trip), with analytical
    wire-size accounting in bits_per_element."""
    name: str
    fn: Callable          # (x, key) -> x̃
    bits_per_element: float

    def __call__(self, x, key):
        return self.fn(x, key)

    def ratio(self) -> float:
        return 32.0 / self.bits_per_element

    def compress_with_feedback(self, grads, residual, key=None):
        """Error-feedback compression over a pytree (survey: local gradient
        accumulation). Returns (compressed grads, new residual)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        res_leaves = jax.tree_util.tree_leaves(residual)
        keys = jax.random.split(key, len(leaves))
        outs, new_res = [], []
        for g, r, k in zip(leaves, res_leaves, keys):
            corrected = g.astype(jnp.float32) + r
            sent = self.fn(corrected, k)
            outs.append(sent.astype(g.dtype))
            new_res.append(corrected - sent)
        return (jax.tree_util.tree_unflatten(treedef, outs),
                jax.tree_util.tree_unflatten(treedef, new_res))


def make_compressor(name: str, *, bits=8, frac=0.01, tau=1e-3, block=256) -> Compressor:
    if name == "none":
        return Compressor("none", lambda x, k: x.astype(jnp.float32), 32.0)
    if name == "stochastic_bf16":
        return Compressor(name, lambda x, k: stochastic_round(x, k).astype(jnp.float32), 16.0)
    if name in ("int8", "int4", "qsgd"):
        b = {"int8": 8, "int4": 4}.get(name, bits)
        def f(x, k, b=b):
            q, s, sh = quantize_int(x, k, bits=b, block=block)
            return dequantize_int(q, s, sh)
        return Compressor(name, f, b + 32.0 / block)
    if name == "ternary":
        return Compressor(name, ternarize, math.log2(3))
    if name == "onebit":
        return Compressor(name, lambda x, k: onebit(x), 1.0)
    if name == "topk":
        # value (32b) + index (32b) per kept element
        return Compressor(name, lambda x, k: topk_sparsify(x, frac), 64.0 * frac)
    if name == "topk_int8":
        # wire format: per KEPT element, 32b index + 8b value + amortized
        # per-block scale over kept values (Strom-2015-style sparse payload)
        def f(x, k):
            q, s, sh = quantize_int(topk_sparsify(x, frac), k, bits=8, block=block)
            return dequantize_int(q, s, sh)
        return Compressor(name, f, frac * (8 + 32 + 32.0 / block))
    if name == "threshold":
        return Compressor(name, lambda x, k: threshold_sparsify(x, tau), 64.0 * frac)
    raise ValueError(f"unknown compressor {name!r}")


# --------------------------------------------------- DGC momentum correction
def dgc_update(grads, velocity, residual, frac=0.01, momentum=0.9):
    """Deep Gradient Compression [Lin et al. 2018]: accumulate *velocity*
    locally (momentum correction) and sparsify the accumulated velocity.
    Returns (sent, new_velocity, new_residual)."""
    def per_leaf(g, v, r):
        g = g.astype(jnp.float32)
        v = momentum * v + g                 # local momentum
        acc = r + v                          # local gradient accumulation
        sent = topk_sparsify(acc, frac)
        mask = sent == 0.0
        return sent, v * mask, acc * mask    # clear sent coordinates

    trip = jax.tree.map(per_leaf, grads, velocity, residual)
    sent = jax.tree.map(lambda t: t[0], trip, is_leaf=lambda x: isinstance(x, tuple))
    vel = jax.tree.map(lambda t: t[1], trip, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[2], trip, is_leaf=lambda x: isinstance(x, tuple))
    return sent, vel, res
