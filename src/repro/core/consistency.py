"""Model-consistency spectrum (survey §6.1): sync / stale-sync / async.

TPU SPMD execution is bulk-synchronous, so HOGWILD-style lock-free updates
have no direct analogue (DESIGN.md §2). We *simulate* the semantics
deterministically: K virtual training agents step round-robin; each agent
computes its gradient against a parameter copy that is `staleness` updates
old (a bounded gradient-delay queue). This reproduces the survey's
staleness-vs-convergence trade-off (Fig 28's spectrum) measurably:

  staleness = 0              synchronous data-parallel SGD
  staleness <= s (bounded)   stale-synchronous parallel (SSP) [Ho et al. 2013]
  staleness ~ K (unbounded)  asynchronous / Downpour-style [Dean et al. 2012]

The whole simulation runs under jax.lax control flow, so it jits.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def simulate_stale_sgd(loss_fn, params0, batches, *, lr=0.1, staleness=0,
                       agents=4):
    """Run len(batches) SGD updates where each gradient is computed at the
    parameter version from `staleness` steps ago (survey §6.1's w^(t−τ)).

    loss_fn(params, batch) -> scalar. batches: pytree stacked on axis 0,
    length divisible by 1. Returns (final params, losses per step).
    """
    hist0 = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (staleness + 1,) + p.shape).copy(),
        params0)

    def step(carry, batch):
        params, hist = carry
        stale = jax.tree.map(lambda h: h[0], hist)          # oldest in window
        loss, grads = jax.value_and_grad(loss_fn)(stale, batch)
        new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        hist = jax.tree.map(
            lambda h, n: jnp.concatenate([h[1:], n[None]], axis=0), hist, new)
        return (new, hist), loss

    (final, _), losses = jax.lax.scan(step, (params0, hist0), batches)
    return final, losses


def simulate_async_agents(loss_fn, params0, batches, *, lr=0.1, agents=4):
    """Downpour-style simulation: `agents` workers each hold a local copy
    fetched when they last pushed; pushes happen round-robin, so every
    gradient arrives exactly `agents−1` versions stale. Returns (params,
    losses)."""
    local0 = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (agents,) + p.shape).copy(), params0)

    def step(carry, xs):
        params, local = carry
        t, batch = xs
        a = t % agents
        mine = jax.tree.map(lambda l: l[a], local)
        loss, grads = jax.value_and_grad(loss_fn)(mine, batch)
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)  # push
        local = jax.tree.map(lambda l, p: l.at[a].set(p), local, params)  # fetch
        return (params, local), loss

    n = len(jax.tree_util.tree_leaves(batches)[0])
    (final, _), losses = jax.lax.scan(
        step, (params0, local0), (jnp.arange(n), batches))
    return final, losses
