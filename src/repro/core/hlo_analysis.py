"""Loop-aware analysis of optimized XLA HLO — the dry-run "profiler".

XLA's `compiled.cost_analysis()` counts a `while` body **once**, ignoring the
trip count — useless for scan-over-layers models. We therefore parse the
optimized HLO module text ourselves and compute, with trip-count
multiplication through nested loops:

  * `flops`            — 2·|out|·|contraction| per dot/convolution (MXU work)
  * `hbm_bytes`        — HBM traffic model: per top-level op (a fusion is one
                         kernel), operand bytes + result bytes
  * `collective_bytes` — result bytes of all-gather / all-reduce /
                         reduce-scatter / all-to-all / collective-permute

Trip counts are read from each while's condition region (`constant(N)` fed to
the loop compare). XLA's loop widening ("wide." regions hold k copies of the
body with trip N/k) stays consistent: trip × body-cost is invariant.

Validated in tests against analytical 6·N·D FLOPs and against unrolled
lowerings of the same program.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 0.5, "u4": 0.5, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                   "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^((?:\([^=]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
                    r"([\w\-]+)\(")
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                      r"\{?%?([\w.\-]+(?:,\s*%[\w.\-]+)*)\}?")


def _shape_list_bytes(text):
    """Total bytes of all shape tokens in `text`."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_text):
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclass
class Instr:
    name: str
    result: str          # result shape text (may be a tuple)
    op: str
    rest: str            # full rhs text

    @property
    def result_bytes(self):
        return _shape_list_bytes(self.result)


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    by_name: dict = field(default_factory=dict)


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and "{" in line and ("%" in line or line.startswith("ENTRY")):
            # computation header: `%name (params) -> shape {` or `ENTRY %name ...`
            m = re.search(r"%([\w.\-]+)\s*\(", line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        om = _OP_RE.match(rhs)
        if om:
            result, op = om.group(1), om.group(2)
        else:
            # e.g. `%p = (tuple...) parameter(0)` handled above; fallback
            result, op = rhs.split(")")[0] + ")", "unknown"
            w = re.search(r"\)\s*([\w\-]+)\(", rhs)
            if w:
                op = w.group(1)
        ins = Instr(name, result, op, rhs)
        cur.instrs.append(ins)
        cur.by_name[name] = ins
    return comps


def _trip_count(comps, cond_name) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []
    for ins in cond.instrs:
        m = re.search(r"constant\((-?\d+)\)", ins.rest)
        if m:
            consts.append(int(m.group(1)))
        # compare may live in a fusion region
        cm = _CALL_RE.search(ins.rest)
        if cm and ins.op == "fusion":
            sub = comps.get(cm.group(1).split(",")[0].strip().lstrip("%"))
            if sub:
                for si in sub.instrs:
                    m2 = re.search(r"constant\((-?\d+)\)", si.rest)
                    if m2:
                        consts.append(int(m2.group(1)))
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else 1


def _dot_flops(ins: Instr, comp: Computation, comps) -> float:
    """2 × |output| × |contracting dims| (+ batch handled via output size)."""
    out_elems = _shape_elems(ins.result)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    if not m:
        return 2.0 * out_elems
    cdims = [int(d) for d in m.group(1).split(",") if d != ""]
    # lhs operand shape: first %name inside parens
    am = re.search(r"\(\s*%([\w.\-]+)", ins.rest)
    contract = 1
    if am:
        op = comp.by_name.get(am.group(1))
        if op is not None:
            sm = _SHAPE_RE.search(op.result)
            if sm and sm.group(2):
                dims = [int(d) for d in sm.group(2).split(",")]
                for c in cdims:
                    if c < len(dims):
                        contract *= dims[c]
    return 2.0 * out_elems * contract


def _conv_flops(ins: Instr) -> float:
    out_elems = _shape_elems(ins.result)
    m = re.search(r"window=\{size=([0-9x]+)", ins.rest)
    k = 1
    if m:
        for d in m.group(1).split("x"):
            k *= int(d)
    return 2.0 * out_elems * k


_cache = {}


def analyze_computation(comps, name, depth=0) -> dict:
    """Recursive (memoized) cost of one computation."""
    key = name
    if key in _cache:
        return _cache[key]
    comp = comps.get(name)
    out = {"flops": 0.0, "hbm_bytes": 0.0,
           "collective_bytes": defaultdict(float), "collective_counts": defaultdict(float)}
    if comp is None or depth > 60:
        return out
    _cache[key] = out  # pre-insert to break cycles
    for ins in comp.instrs:
        op = ins.op
        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "unknown", "after-all"):
            continue
        callees = _CALL_RE.findall(ins.rest)
        if op == "while":
            body = cond = None
            bm = re.search(r"body=%?([\w.\-]+)", ins.rest)
            cm = re.search(r"condition=%?([\w.\-]+)", ins.rest)
            if bm:
                body = bm.group(1)
            if cm:
                cond = cm.group(1)
            trip = _trip_count(comps, cond) if cond else 1
            sub = analyze_computation(comps, body, depth + 1) if body else out
            out["flops"] += trip * sub["flops"]
            out["hbm_bytes"] += trip * sub["hbm_bytes"]
            for k, v in sub["collective_bytes"].items():
                out["collective_bytes"][k] += trip * v
                out["collective_counts"][k] += trip * sub["collective_counts"][k]
            continue
        if op in ("call", "conditional", "async-start"):
            for group in callees:
                for cal in group.split(","):
                    sub = analyze_computation(comps, cal.strip().lstrip("%"), depth + 1)
                    out["flops"] += sub["flops"]
                    out["hbm_bytes"] += sub["hbm_bytes"]
                    for k, v in sub["collective_bytes"].items():
                        out["collective_bytes"][k] += v
                        out["collective_counts"][k] += sub["collective_counts"][k]
            continue
        if op == "fusion":
            # one kernel: HBM traffic = operands + result; flops from inside.
            # In-place loop fusions (dynamic-update-slice root, XLA aliases
            # the buffer) touch only the updated slice, not the whole buffer:
            # count the non-buffer operands + 2x the smallest-operand proxy.
            operand_names = re.findall(r"%([\w.\-]+)", ins.rest.split("),")[0])
            operand_sizes = [comp.by_name[on].result_bytes
                             for on in operand_names if on in comp.by_name]
            if "dynamic_update_slice" in ins.rest or "DynamicUpdateSlice" in ins.rest:
                big = max(operand_sizes, default=0.0)
                if ins.result_bytes >= big > 0:  # buffer aliased through
                    out["hbm_bytes"] += 2.0 * max(sum(operand_sizes) - big,
                                                  0.05 * big)
                else:
                    out["hbm_bytes"] += sum(operand_sizes) + ins.result_bytes
            else:
                out["hbm_bytes"] += sum(operand_sizes) + ins.result_bytes
            cm2 = re.search(r"calls=%?([\w.\-]+)", ins.rest)
            if cm2:
                sub = analyze_computation(comps, cm2.group(1), depth + 1)
                out["flops"] += sub["flops"]
                for k, v in sub["collective_bytes"].items():
                    out["collective_bytes"][k] += v
                    out["collective_counts"][k] += sub["collective_counts"][k]
            continue

        # plain op
        base = None
        for c in _COLLECTIVE_OPS:
            if op == c or op == c + "-start":
                base = c
                break
        if base:
            out["collective_bytes"][base] += ins.result_bytes
            out["collective_counts"][base] += 1
        if op in ("dot",):
            out["flops"] += _dot_flops(ins, comp, comps)
        elif op == "convolution":
            out["flops"] += _conv_flops(ins)
        elif op == "custom-call" and ("matmul" in ins.rest or "dot" in ins.rest):
            out["flops"] += 2.0 * _shape_elems(ins.result)  # conservative
        # HBM traffic for non-fusion compute ops. Sliced reads/writes touch
        # only the slice, not the full operand (scan weight indexing would
        # otherwise count the whole stacked tensor per trip).
        if op in ("dynamic-slice", "slice", "gather", "broadcast", "reshape",
                  "transpose", "copy"):
            out["hbm_bytes"] += 2.0 * ins.result_bytes
        elif op in ("dynamic-update-slice", "scatter"):
            operand_names = re.findall(r"%([\w.\-]+)", ins.rest)
            upd = 0.0
            if len(operand_names) >= 2 and operand_names[1] in comp.by_name:
                upd = comp.by_name[operand_names[1]].result_bytes
            out["hbm_bytes"] += 2.0 * (upd or ins.result_bytes)
        elif op not in ("copy-start", "copy-done"):
            operand_names = re.findall(r"%([\w.\-]+)", ins.rest)
            operand_bytes = sum(
                comp.by_name[on].result_bytes for on in operand_names
                if on in comp.by_name)
            out["hbm_bytes"] += operand_bytes + ins.result_bytes
    return out


def top_ops(text: str, n=15, metric="hbm_bytes") -> list:
    """Trip-weighted per-op cost ranking — the dry-run 'profile' used by the
    §Perf hypothesis loop. Returns [(cost, op, name, metadata_hint)]."""
    _cache.clear()
    comps = parse_module(text)
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    entry = m.group(1) if m else None
    rows = []

    def visit(name, mult, depth=0):
        comp = comps.get(name)
        if comp is None or depth > 60:
            return
        for ins in comp.instrs:
            op = ins.op
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "unknown", "after-all"):
                continue
            if op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.rest)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                trip = _trip_count(comps, cm.group(1)) if cm else 1
                if bm:
                    visit(bm.group(1), mult * trip, depth + 1)
                continue
            if op in ("call", "conditional"):
                for group in _CALL_RE.findall(ins.rest):
                    for cal in group.split(","):
                        visit(cal.strip().lstrip("%"), mult, depth + 1)
                continue
            if metric == "flops":
                cost = _dot_flops(ins, comp, comps) if op == "dot" else (
                    _conv_flops(ins) if op == "convolution" else 0.0)
                if op == "fusion":
                    cm2 = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                    if cm2:
                        cost = analyze_computation(comps, cm2.group(1))["flops"]
            else:
                if op == "fusion":
                    operand_names = re.findall(r"%([\w.\-]+)",
                                               ins.rest.split("),")[0])
                    sizes = [comp.by_name[o].result_bytes for o in operand_names
                             if o in comp.by_name]
                    if ("dynamic_update_slice" in ins.rest
                            and ins.result_bytes >= max(sizes, default=0) > 0):
                        cost = 2.0 * max(sum(sizes) - max(sizes),
                                         0.05 * max(sizes))
                    else:
                        cost = ins.result_bytes + sum(sizes)
                elif op in ("dynamic-slice", "slice", "gather", "broadcast",
                            "reshape", "transpose", "copy"):
                    cost = 2.0 * ins.result_bytes
                else:
                    operand_names = re.findall(r"%([\w.\-]+)", ins.rest)
                    cost = ins.result_bytes + sum(
                        comp.by_name[o].result_bytes for o in operand_names
                        if o in comp.by_name)
            if cost:
                hint = ""
                hm = re.search(r'op_name="([^"]*)"', ins.rest)
                if hm:
                    hint = hm.group(1)[-90:]
                rows.append((cost * mult, op, ins.name,
                             _SHAPE_RE.search(ins.result).group(0)
                             if _SHAPE_RE.search(ins.result) else "", hint))

    if entry:
        visit(entry, 1.0)
    rows.sort(key=lambda r: -r[0])
    return rows[:n]


def analyze_hlo_text(text: str) -> dict:
    """Loop-aware module cost. Entry = the computation named in `ENTRY`."""
    _cache.clear()
    comps = parse_module(text)
    entry = None
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    if m:
        entry = m.group(1)
    if entry not in comps:
        # fall back: the computation with the most instructions
        entry = max(comps, key=lambda c: len(comps[c].instrs)) if comps else None
    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0, "collective_bytes": {}, "total_collective_bytes": 0.0}
    res = analyze_computation(comps, entry)
    res = {
        "flops": res["flops"],
        "hbm_bytes": res["hbm_bytes"],
        "collective_bytes": dict(res["collective_bytes"]),
        "collective_counts": dict(res["collective_counts"]),
    }
    res["total_collective_bytes"] = sum(res["collective_bytes"].values())
    return res


# ------------------------------------------------------------- jax interface
def cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def analyze_compiled(lowered, compiled) -> dict:
    ca = cost_analysis_dict(compiled)
    try:
        text = compiled.as_text()
    except Exception:
        text = lowered.as_text()
    loop_aware = analyze_hlo_text(text)
    return {
        "xla_cost_flops": float(ca.get("flops", 0.0)),
        "xla_cost_bytes": float(ca.get("bytes accessed", 0.0)),
        "flops": loop_aware["flops"],
        "hbm_bytes": loop_aware["hbm_bytes"],
        "collectives": {
            "total": loop_aware["total_collective_bytes"],
            "by_op": loop_aware["collective_bytes"],
            "counts": loop_aware["collective_counts"],
        },
        "memory": memory_analysis_dict(compiled),
    }


def collective_bytes(hlo_text: str, per_op: bool = False):
    """Loop-aware collective byte count from HLO text."""
    res = analyze_hlo_text(hlo_text)
    out = {"total": res["total_collective_bytes"], "by_op": res["collective_bytes"],
           "counts": res["collective_counts"]}
    return out if per_op else out["total"]
