"""Model consolidation — survey §6.4: the inconsistent end of the parameter-
consistency spectrum (Fig 28).

* Ensemble learning (§6.4.1): separately trained members, averaged
  *predictions* — "a completely parallel process, requiring no communication
  between the agents".
* Knowledge distillation (§6.4.1) [Ba & Caruana; Hinton et al.]: a student
  trained to mimic ensemble logits.
* Model averaging (§6.4.2): one-shot (ParallelSGD [Zinkevich et al.]) and
  periodic averaging; Elastic Averaging SGD [Zhang et al. 2015] with the
  elastic force ρ(w_i − w̄) between agents and the center variable.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


# ------------------------------------------------------------------ ensembles
def ensemble_logits(apply_fn, members, x):
    """Average member predictions: f(x) = 1/m Σ f_{w_i}(x) (§6.4.1)."""
    logits = jnp.stack([apply_fn(w, x) for w in members])
    return jnp.mean(logits, axis=0)


def average_params(members):
    """One-shot parameter averaging (ParallelSGD consolidation)."""
    return jax.tree.map(lambda *ws: sum(ws) / len(ws), *members)


# --------------------------------------------------------------- distillation
def distill_loss(student_logits, teacher_logits, temperature=2.0):
    """KL(teacher‖student) at temperature T (Hinton et al. 2015)."""
    t = jax.nn.softmax(teacher_logits / temperature, axis=-1)
    ls = jax.nn.log_softmax(student_logits / temperature, axis=-1)
    return -jnp.mean(jnp.sum(t * ls, axis=-1)) * temperature ** 2


# ---------------------------------------------------------------------- EASGD
def easgd_round(agents, center, grads, *, lr=0.1, rho=0.1):
    """One EASGD update for every agent + the center variable w̄:

        w_i ← w_i − lr·(g_i + ρ(w_i − w̄))
        w̄   ← w̄ + lr·ρ·Σ_i (w_i − w̄)

    The elastic force lets agents explore away from the center while pulling
    the ensemble together — communication happens only through w̄ (a PS).
    """
    new_agents = []
    pull = jax.tree.map(jnp.zeros_like, center)
    for w, g in zip(agents, grads):
        diff = jax.tree.map(lambda a, c: a - c, w, center)
        new_agents.append(jax.tree.map(
            lambda a, g_, d: a - lr * (g_ + rho * d), w, g, diff))
        pull = jax.tree.map(lambda p, d: p + d, pull, diff)
    new_center = jax.tree.map(lambda c, p: c + lr * rho * p, center, pull)
    return new_agents, new_center


def periodic_average_sgd(loss_fn, params0, batches, *, agents=4, lr=0.1,
                         avg_every=10):
    """§6.4.2 periodic model averaging: m independent SGD streams averaged
    every k steps. Returns (final averaged params, per-step mean losses)."""
    ws = [params0 for _ in range(agents)]
    n = len(jax.tree_util.tree_leaves(batches)[0])
    losses = []
    gfn = jax.jit(jax.value_and_grad(loss_fn))
    for t in range(n):
        batch = jax.tree.map(lambda b: b[t], batches)
        step_losses = []
        for i in range(agents):
            li, gi = gfn(ws[i], batch)
            ws[i] = jax.tree.map(lambda w, g: w - lr * g, ws[i], gi)
            step_losses.append(float(li))
        losses.append(sum(step_losses) / agents)
        if (t + 1) % avg_every == 0:
            avg = average_params(ws)
            ws = [avg for _ in range(agents)]
    return average_params(ws), losses
