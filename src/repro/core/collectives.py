"""Allreduce algorithms from survey §2.5, as shard_map-composable schedules.

The survey's four algorithms are re-implemented with `lax.ppermute` /
`lax.all_gather` so their *structure* (number of communication steps, bytes
per step) is visible in HLO and checkable against the α-β cost model
(`core.costmodel`):

  tree          reduce-to-root then broadcast:      T = 2·log2(P)(L + γmG)
  butterfly     recursive doubling:                 T = log2(P)(L + γmG)
  ring          bandwidth-optimal pipeline:         T = 2(P−1)(L + γ(m/P)G)
                (reduce-scatter ring + allgather ring)
  rabenseifner  reduce-scatter (halving) + allgather(doubling):
                                                    T = 2L·log2(P) + 2γmG(P−1)/P
  psum          XLA's native allreduce (the production default)

All run inside `shard_map` over a named mesh axis. For non-power-of-two axis
sizes, tree/butterfly/rabenseifner fall back to psum (the survey analyzes
them for P = 2^k).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

ALGORITHMS = ("psum", "ring", "tree", "butterfly", "rabenseifner")


def _axis_size(axis):
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return int(lax.psum(1, axis))   # older jax: psum of a constant is static


def _is_pow2(n):
    return n & (n - 1) == 0


# -------------------------------------------------------------------- helpers
def _perm(axis_size, shift):
    return [(i, (i + shift) % axis_size) for i in range(axis_size)]


def allreduce_sum(x, axis, algorithm="psum"):
    """Allreduce-sum of `x` over mesh axis `axis` (inside shard_map)."""
    if algorithm == "psum":
        return lax.psum(x, axis)
    P = _axis_size(axis)
    if P == 1:
        return x
    if algorithm == "ring":
        return _ring_allreduce(x, axis, P)
    if not _is_pow2(P):
        return lax.psum(x, axis)
    if algorithm == "tree":
        return _tree_allreduce(x, axis, P)
    if algorithm == "butterfly":
        return _butterfly_allreduce(x, axis, P)
    if algorithm == "rabenseifner":
        return _rabenseifner_allreduce(x, axis, P)
    raise ValueError(f"unknown algorithm {algorithm!r}")


def allreduce_mean(x, axis, algorithm="psum"):
    return allreduce_sum(x, axis, algorithm) / _axis_size(axis)


# ------------------------------------------------------------------ butterfly
def _butterfly_allreduce(x, axis, P):
    """Recursive doubling: log2(P) steps, full message per step."""
    idx = lax.axis_index(axis)
    for k in range(int(math.log2(P))):
        shift = 1 << k
        # pair-wise exchange with partner idx ^ shift: ppermute both ways,
        # each rank picks the direction its partner lives in.
        fwd = lax.ppermute(x, axis, _perm(P, shift))        # from idx − shift
        bwd = lax.ppermute(x, axis, _perm(P, P - shift))    # from idx + shift
        partner_above = (idx // shift) % 2 == 0
        x = jax.tree.map(lambda a, u, v: a + jnp.where(partner_above, u, v),
                         x, bwd, fwd)
    return x


def _select(pred, a, b):
    return jax.tree.map(lambda u, v: jnp.where(pred, u, v), a, b)


# ----------------------------------------------------------------------- tree
def _tree_allreduce(x, axis, P):
    """Binomial-tree reduce to rank 0, then broadcast: 2·log2(P) steps.

    Structurally faithful (2 log P dependent steps with full-size messages);
    implemented with masked ppermute exchanges.
    """
    idx = lax.axis_index(axis)
    # reduce phase: at step k, ranks with idx % 2^(k+1) == 2^k send to idx−2^k
    for k in range(int(math.log2(P))):
        shift = 1 << k
        moved = lax.ppermute(x, axis, _perm(P, P - shift))  # from idx+shift
        is_recv = (idx % (2 * shift)) == 0
        x = jax.tree.map(lambda a, m: a + jnp.where(is_recv, m, 0.0).astype(a.dtype),
                         x, moved)
    # broadcast phase: root sends down the tree (log2(P) masked steps)
    for k in reversed(range(int(math.log2(P)))):
        shift = 1 << k
        moved = lax.ppermute(x, axis, _perm(P, shift))      # from idx−shift
        use = (idx % (2 * shift)) == shift
        x = jax.tree.map(lambda a, m: jnp.where(use, m, a), x, moved)
    return x


# ----------------------------------------------------------------------- ring
def _ring_allreduce(x, axis, P):
    """Bandwidth-optimal ring: reduce-scatter (P−1 steps of m/P) then
    allgather (P−1 steps of m/P) — the survey's `T_pipe` pipeline."""
    flat, treedef = jax.tree_util.tree_flatten(x)
    sizes = [f.size for f in flat]
    shapes = [f.shape for f in flat]
    v = jnp.concatenate([f.reshape(-1) for f in flat]) if len(flat) > 1 else flat[0].reshape(-1)
    n = v.size
    pad = (-n) % P
    v = jnp.pad(v, (0, pad)).reshape(P, (n + pad) // P)

    idx = lax.axis_index(axis)
    perm_next = _perm(P, 1)  # send to rank+1

    # reduce-scatter ring: after P−1 steps rank r owns the full sum of chunk r
    buf = v[(idx - 1) % P]
    for k in range(1, P - 1):
        buf = lax.ppermute(buf, axis, perm_next)
        buf = buf + v[(idx - k - 1) % P]
    owned = lax.ppermute(buf, axis, perm_next) + v[idx]

    # allgather ring: circulate owned chunks P−1 steps
    cur = owned
    out = jnp.zeros_like(v)
    out = out.at[idx].set(owned)
    for k in range(1, P):
        cur = lax.ppermute(cur, axis, perm_next)
        out = out.at[(idx - k) % P].set(cur)
    res = out.reshape(-1)[:n]
    if len(flat) == 1:
        return res.reshape(shapes[0])
    outs = []
    off = 0
    for s, shp in zip(sizes, shapes):
        outs.append(res[off:off + s].reshape(shp))
        off += s
    return jax.tree_util.tree_unflatten(treedef, outs)


# --------------------------------------------------------------- rabenseifner
def _rabenseifner_allreduce(x, axis, P):
    """Reduce-scatter via recursive *halving* + allgather via recursive
    *doubling*: 2·log2(P) latency steps, 2γm(P−1)/P bandwidth — achieves the
    survey's allreduce lower bound. Message size halves (then doubles) each
    step, visible in the lowered HLO as shrinking/growing ppermute operands.
    """
    flat, treedef = jax.tree_util.tree_flatten(x)
    shapes = [f.shape for f in flat]
    sizes = [f.size for f in flat]
    v = jnp.concatenate([f.reshape(-1) for f in flat]) if len(flat) > 1 else flat[0].reshape(-1)
    n = v.size
    pad = (-n) % P
    v = jnp.pad(v, (0, pad))
    m = v.size
    idx = lax.axis_index(axis)
    logp = int(math.log2(P))

    # ---- reduce-scatter (recursive halving), partner distance P/2 → 1
    off = jnp.int32(0)
    seg = m
    d = P // 2
    for _ in range(logp):
        half = seg // 2
        bit = (idx // d) % 2                       # 0: keep lower, partner above
        keep_off = off + bit * half
        send_off = off + (1 - bit) * half
        send = lax.dynamic_slice(v, (send_off,), (half,))
        fwd = lax.ppermute(send, axis, _perm(P, d))        # from idx − d
        bwd = lax.ppermute(send, axis, _perm(P, P - d))    # from idx + d
        recv = _select(bit == 0, bwd, fwd)
        keep = lax.dynamic_slice(v, (keep_off,), (half,)) + recv
        v = lax.dynamic_update_slice(v, keep, (keep_off,))
        off, seg, d = keep_off, half, d // 2

    # ---- allgather (recursive doubling), partner distance 1 → P/2
    d = 1
    for _ in range(logp):
        bit = (idx // d) % 2
        send = lax.dynamic_slice(v, (off,), (seg,))
        fwd = lax.ppermute(send, axis, _perm(P, d))
        bwd = lax.ppermute(send, axis, _perm(P, P - d))
        recv = _select(bit == 0, bwd, fwd)
        partner_off = off + (1 - 2 * bit) * seg
        v = lax.dynamic_update_slice(v, recv, (jnp.maximum(partner_off, 0),))
        off = off - bit * seg
        seg, d = seg * 2, d * 2

    return _unflatten(v[:n], treedef, shapes, sizes)


def _unflatten(res, treedef, shapes, sizes):
    if len(shapes) == 1:
        return jax.tree_util.tree_unflatten(treedef, [res.reshape(shapes[0])])
    outs = []
    off = 0
    for s, shp in zip(sizes, shapes):
        outs.append(res[off:off + s].reshape(shp))
        off += s
    return jax.tree_util.tree_unflatten(treedef, outs)


# ------------------------------------------------------------- step counters
def schedule_steps(algorithm: str, P: int) -> int:
    """Number of dependent communication steps (for structural tests)."""
    if P == 1:
        return 0
    if algorithm == "tree":
        return 2 * int(math.log2(P))
    if algorithm == "butterfly":
        return int(math.log2(P))
    if algorithm == "ring":
        return 2 * (P - 1)
    if algorithm == "rabenseifner":
        return 2 * int(math.log2(P))
    return 1
