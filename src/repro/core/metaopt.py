"""Meta-optimization — survey §6.5: hyper-parameter search as embarrassingly
parallel training agents.

* `grid_search` (§6.5.2 "the prominent method … parameter sweeps"):
  exhaustive sweep, trivially parallel (each config is an independent agent).
* `random_search`: samples log-uniform configs.
* `population_based_training` (Jaderberg et al. 2017, Fig 29): a population
  of agents trains in parallel; every `ready` steps an agent *exploits* (a
  random opponent's weights+hypers replace its own if the opponent is
  better) and *explores* (perturbs the copied hyper-parameters). Decentral,
  nondeterministic-communication topology — the survey's closing example of
  concurrency in meta-optimization.

All utilities take a `train_eval(hypers, steps, state) -> (state, score)`
callback, so they compose with any substrate trainer.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass

import numpy as np


def grid_search(train_eval, grid: dict, steps: int):
    """grid: {name: [values]}. Returns (best_hypers, best_score, table)."""
    keys = list(grid)
    table = []
    best = (None, -math.inf)
    for combo in itertools.product(*(grid[k] for k in keys)):
        hypers = dict(zip(keys, combo))
        _, score = train_eval(hypers, steps, None)
        table.append((hypers, score))
        if score > best[1]:
            best = (hypers, score)
    return best[0], best[1], table


def random_search(train_eval, space: dict, steps: int, trials: int, seed=0):
    """space: {name: (lo, hi)} sampled log-uniformly."""
    rng = np.random.default_rng(seed)
    best = (None, -math.inf)
    table = []
    for _ in range(trials):
        hypers = {k: float(np.exp(rng.uniform(np.log(lo), np.log(hi))))
                  for k, (lo, hi) in space.items()}
        _, score = train_eval(hypers, steps, None)
        table.append((hypers, score))
        if score > best[1]:
            best = (hypers, score)
    return best[0], best[1], table


@dataclass
class PBTAgent:
    hypers: dict
    state: object
    score: float = -math.inf


def population_based_training(train_eval, init_hypers, *, population=4,
                              rounds=5, steps_per_round=10, perturb=1.25,
                              seed=0):
    """Fig 29's explore/exploit loop. init_hypers: list of dicts (len =
    population). Returns (best agent, history)."""
    rng = np.random.default_rng(seed)
    agents = [PBTAgent(dict(h), None) for h in init_hypers]
    history = []
    for r in range(rounds):
        for a in agents:
            a.state, a.score = train_eval(a.hypers, steps_per_round, a.state)
        ranked = sorted(agents, key=lambda a: a.score)
        history.append([(dict(a.hypers), a.score) for a in agents])
        # bottom quartile exploits a random top-quartile agent, then explores
        q = max(1, population // 4)
        for loser in ranked[:q]:
            winner = ranked[-1 - rng.integers(q)]
            loser.state = winner.state
            loser.hypers = {
                k: v * (perturb if rng.random() < 0.5 else 1.0 / perturb)
                for k, v in winner.hypers.items()}
    best = max(agents, key=lambda a: a.score)
    return best, history
