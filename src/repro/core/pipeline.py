"""Layer pipelining (survey §5.3) — GPipe-style microbatch schedule over a
named mesh axis, built from `shard_map` + `lax.ppermute`.

Each of the S stages holds its own contiguous slice of layers; M microbatches
flow through; activations hop stage→stage with ppermute. The bubble fraction
(S−1)/(S−1+M) matches `costmodel.pipeline_bubble_fraction` — the survey's
"latency proportional to the number of processors" disadvantage — and is
validated structurally in tests (number of ppermute rounds = M + S − 1).

This is the composable runner used by examples/pipeline_training.py; the 40
dry-runs use DP+TP plans instead (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(stage_fn, params_stacked, x_microbatches, mesh, axis="stage"):
    """Run M microbatches through S pipeline stages.

    stage_fn(stage_params, x) -> x          (one stage's computation)
    params_stacked: pytree with leading dim S (sharded over `axis`)
    x_microbatches: (M, mb, ...) input microbatches (replicated)
    Returns (M, mb, ...) outputs (replicated).

    Schedule: M + S − 1 rounds; in round r, stage s processes microbatch
    r − s (if valid); activations ppermute to s+1 after each round.
    """
    S = mesh.shape[axis]
    M = x_microbatches.shape[0]

    def per_stage(params, xs):
        params = jax.tree.map(lambda p: p[0], params)      # local stage slice
        xs = xs                                            # (M, mb, ...) replicated
        sid = lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        buf = jnp.zeros(mb_shape, xs.dtype)                # activation in flight
        outs = jnp.zeros((M,) + mb_shape, xs.dtype)

        def round_fn(r, carry):
            buf, outs = carry
            # stage 0 injects microbatch r; others use the incoming buffer
            inject = lax.dynamic_index_in_dim(xs, jnp.clip(r, 0, M - 1), 0,
                                              keepdims=False)
            cur = jnp.where(sid == 0, inject, buf)
            mb_id = r - sid                                # which microbatch
            valid = (mb_id >= 0) & (mb_id < M)
            y = stage_fn(params, cur)
            y = jnp.where(valid, y, cur)
            # last stage records finished microbatch
            outs = lax.cond(
                valid & (sid == S - 1),
                lambda o: lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(mb_id, 0, M - 1), 0),
                lambda o: o, outs)
            # hop to next stage
            buf = lax.ppermute(y, axis, [(i, (i + 1) % S) for i in range(S)])
            return buf, outs

        buf, outs = lax.fori_loop(0, M + S - 1, round_fn, (buf, outs))
        # gather outputs from the last stage to everyone
        outs = lax.psum(jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs[None]

    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), params_stacked), P()),
        out_specs=P(axis), check_vma=False)
    out = fn(params_stacked, x_microbatches)   # (S, M, ...) — identical copies
    return out[0]


def num_pipeline_rounds(stages: int, microbatches: int) -> int:
    return microbatches + stages - 1
