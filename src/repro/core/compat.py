"""jax version compatibility shims (the container pins an older jax than the
APIs this repo targets)."""
from __future__ import annotations


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """`jax.shard_map` with the modern keyword signature; falls back to
    `jax.experimental.shard_map` (where `check_vma` was `check_rep`)."""
    try:
        from jax import shard_map as sm
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)
