"""Analytical cost models from survey §2.5 (α-β / simplified LogP) plus the
parallelism communication-volume models of §5 and the roofline terms used by
the dry-run analysis.

All times in seconds; m = number of elements, gamma = bytes per element,
L = α latency, G = β cost/byte, P = #processors.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


# ------------------------------------------------------ §2.5 collective models
def t_tree(P, m, L, G, gamma=4):
    return 2 * math.log2(P) * (L + gamma * m * G)


def t_butterfly(P, m, L, G, gamma=4):
    return math.log2(P) * (L + gamma * m * G)


def t_pipeline(P, m, L, G, gamma=4):
    return 2 * (P - 1) * (L + gamma * (m / P) * G)


def t_rabenseifner(P, m, L, G, gamma=4):
    return 2 * L * math.log2(P) + 2 * gamma * m * G * (P - 1) / P


def t_lower_bound(P, m, L, G, gamma=4):
    """T ≥ L·log2(P) + 2γmG(P−1)/P [Chan et al. 2007, no redundant compute]."""
    return L * math.log2(P) + 2 * gamma * m * G * (P - 1) / P


def best_allreduce(P, m, L, G, gamma=4):
    algos = {
        "tree": t_tree(P, m, L, G, gamma),
        "butterfly": t_butterfly(P, m, L, G, gamma),
        "ring": t_pipeline(P, m, L, G, gamma),
        "rabenseifner": t_rabenseifner(P, m, L, G, gamma),
    }
    return min(algos.items(), key=lambda kv: kv[1])


def t_parameter_server(P, m, L, G, gamma=4):
    """PS ≡ reduce-then-broadcast = T_tree (survey §6.2)."""
    return t_tree(P, m, L, G, gamma)


# ------------------------------------------- §5 parallelism comm volume/step
def dp_comm_bytes(n_params, gamma=4):
    """Data parallelism: one gradient allreduce per step (§5.1)."""
    return gamma * n_params


def tp_comm_bytes_fc(batch, d_in, d_out, layers, gamma=4):
    """Model parallelism on FC stacks: activations all-gathered per layer
    boundary (§5.2's all-to-all)."""
    return gamma * batch * (d_in + d_out) * layers


def hybrid_comm_bytes(n_conv_params, n_fc_params, batch, fc_act, gamma=4):
    """Krizhevsky hybrid (§5.4): allreduce conv grads + all-to-all FC acts."""
    return gamma * (n_conv_params + batch * fc_act)


def pipeline_bubble_fraction(stages, microbatches):
    """GPipe bubble: (S−1)/(S−1+M) idle fraction (§5.3 latency discussion)."""
    return (stages - 1) / (stages - 1 + microbatches)


# -------------------------------------------------------------- TPU roofline
@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16 FLOP/s per chip (TPU v5e)
    hbm_bw: float = 819e9           # bytes/s per chip
    ici_bw: float = 50e9            # bytes/s per link
    hbm_bytes: float = 16 * 2**30   # 16 GiB


V5E = HW()


def roofline_terms(hlo_flops, hlo_bytes, collective_bytes, chips, hw=V5E):
    """The three §Roofline terms, in seconds (global quantities in, /chips)."""
    return {
        "compute_s": hlo_flops / (chips * hw.peak_flops),
        "memory_s": hlo_bytes / (chips * hw.hbm_bw),
        "collective_s": collective_bytes / (chips * hw.ici_bw),
    }


def dominant_term(terms):
    return max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])


def model_flops(n_params_active, tokens):
    """MODEL_FLOPS = 6·N·D (survey-era rule of thumb; N active for MoE)."""
    return 6.0 * n_params_active * tokens
