"""Shared layer primitives: norms, MLPs, embeddings, RoPE (standard + M-RoPE).

Everything is functional: `init_*` builds a params pytree, `*_apply` is pure.
Parameters are stored in bf16 (config.dtype); math runs in f32 where it
matters (norms, softmax, rope) — survey §6.3 quantization discussion applies
to gradients, not forward numerics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------- init utils
def dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------------- norm
def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


# ------------------------------------------------------------------------ mlp
def init_swiglu(key, d, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, d_ff), dtype),
        "w_in": dense_init(k2, (d, d_ff), dtype),
        "w_out": dense_init(k3, (d_ff, d), dtype),
    }


def swiglu(params, x):
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    h = jnp.einsum("...d,df->...f", x, params["w_in"])
    act = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    return jnp.einsum("...f,fd->...d", act, params["w_out"])


# ----------------------------------------------------------------------- rope
def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta=10_000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs     # (..., S, hd/2)
    angles = angles[..., None, :]                                 # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta=10_000.0, sections=(16, 24, 24)):
    """Qwen2-VL multimodal RoPE. positions3: (3, ..., S) temporal/h/w ids.

    The hd/2 frequency slots are split into 3 sections; each section uses the
    corresponding positional stream. sections must sum to hd/2.
    """
    hd = x.shape[-1]
    half = hd // 2
    secs = np.asarray(sections)
    if secs.sum() != half:  # rescale sections for reduced head dims
        secs = np.round(secs * half / secs.sum()).astype(int)
        secs[-1] = half - secs[:-1].sum()
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)       # (half,)
    # pick, per frequency slot, which positional stream drives it
    sel = np.concatenate([np.full(s, i) for i, s in enumerate(secs)])
    streams = jnp.stack([positions3[i] for i in range(3)], axis=-1)  # (..., S, 3)
    pos = streams[..., sel]                                          # (..., S, half)
    angles = pos.astype(jnp.float32) * freqs                      # (..., S, half)
    angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ embedding
def init_embedding(key, vocab, d, dtype):
    return {"table": embed_init(key, (vocab, d), dtype)}


def embed(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def unembed(params, x):
    """Tied unembedding: (..., d) @ (vocab, d)^T."""
    return jnp.einsum("...d,vd->...v", x, params["table"])
