"""Mixture-of-Experts layer: top-k router + capacity-based sort dispatch.

Survey mapping: experts are the survey's "model parallelism by neurons"
pushed to its modern extreme — expert weights are sharded over the 'model'
mesh axis (expert parallelism) and token dispatch manifests as all-to-all /
all-gather collectives in the lowered HLO (§5.2's all-to-all analysis).

Dispatch algorithm (memory-feasible for 128 experts, unlike one-hot combine):
  1. top-k expert ids per token, flatten to (T*k,) assignments
  2. position-in-expert via sort + segment arithmetic
  3. scatter tokens into an (E, C, D) buffer (capacity C, overflow dropped)
  4. per-expert SwiGLU via batched einsum
  5. scatter-add back weighted by router probs
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = L.dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": L.dense_init(k1, (d, e), jnp.float32),
        "w_gate": L.dense_init(k2, (e, d, f), dt),
        "w_in": L.dense_init(k3, (e, d, f), dt),
        "w_out": L.dense_init(k4, (e, f, d), dt),
    }


def moe_apply(params, x, cfg, constrain=None):
    """x: (B, S, D) -> (B, S, D). constrain: optional fn(tensor, names) that
    applies sharding constraints on the dispatch buffers."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    C = int(np.ceil(T * K / E * cfg.capacity_factor))
    C = max(C, 1)
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                       # (T, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)       # renormalize

    flat_e = top_e.reshape(-1)                                   # (T*K,)
    flat_p = top_p.reshape(-1)

    # position of each assignment within its expert (stable w.r.t. token order)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank within run of equal expert ids
    counts = jnp.bincount(flat_e, length=E)                      # (E,)
    starts = jnp.cumsum(counts) - counts                         # (E,)
    rank_sorted = jnp.arange(T * K) - starts[sorted_e]
    pos = jnp.zeros(T * K, jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))

    keep = pos < C
    safe_e = jnp.where(keep, flat_e, 0)
    safe_pos = jnp.where(keep, pos, C)                           # C = drop slot

    # §Perf: the token stream is NOT gathered by index — flat_t is just
    # repeat(arange(T), K), so jnp.repeat keeps the data-sharding local.
    # (The baseline's xt[flat_t] gather lowered to a full (T·K, D) f32
    # all-reduce per layer: 13.2e12 B/device, the dominant collective.)
    xt_rep = jnp.repeat(xt, K, axis=0) * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E, C + 1, D), x.dtype)
    buf = buf.at[safe_e, safe_pos].add(xt_rep)
    buf = buf[:, :C]                                             # (E, C, D)
    if constrain is not None:
        buf = constrain(buf, ("expert", "capacity", None))

    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    h = jnp.einsum("ecd,edf->ecf", buf, params["w_in"])
    act = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    y = jnp.einsum("ecf,efd->ecd", act, params["w_out"])         # (E, C, D)
    if constrain is not None:
        y = constrain(y, ("expert", "capacity", None))

    # combine as a scatter-add keyed by an inverse (expert, slot) -> token
    # map, so the reduction over expert shards happens on the (T, D) output
    # in bf16 — not on a gathered (T·K, D) f32 intermediate (§Perf).
    tok_ids = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    token_of = jnp.full((E, C + 1), T, jnp.int32).at[safe_e, safe_pos].set(tok_ids)
    w_of = jnp.zeros((E, C + 1), jnp.float32).at[safe_e, safe_pos].set(flat_p * keep)
    weighted = y * w_of[:, :C, None].astype(y.dtype)             # (E, C, D)
    # batched 2-D scatter indices: no reshape of the sharded (E, C) dims
    # (a flat reshape would all-gather the capacity-sharded buffer, §Perf)
    out = jnp.zeros((T + 1, D), x.dtype).at[token_of[:, :C]].add(weighted)
    return out[:T].reshape(B, S, D)


def moe_apply_ep(params, x, cfg, plan):
    """Expert-parallel fast path (survey §5.2 made communication-optimal).

    Preconditions: num_experts % |model axis| == 0 and the plan shards
    experts over 'model' (qwen3: 128/16 = 8 experts per device).

    Insight: under the dp_tp plan the token activations are *replicated
    across the model axis* (they are sharded over batch axes only), so every
    device already holds the tokens its local experts need — dispatch is
    communication-free. Each device routes its local tokens to its local
    expert slice and the only collective is ONE bf16 psum of the (T_loc, D)
    partial output over 'model' per layer. The XLA-auto baseline instead
    all-gathered (T·K, D) scatter operands (§Perf pair 3: 294s → see
    EXPERIMENTS); this path moves ~1000× fewer bytes.

    Capacity semantics: per-(data-shard × expert) capacity
    C_loc = ceil(T_loc·K/E·capacity_factor) — drops can differ marginally
    from the global-capacity reference (documented approximation).
    """
    from repro.core.compat import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = plan.mesh
    model_axes = plan.tensor_axes            # ("model",)
    batch_axes = plan.batch_axes
    E = cfg.num_experts
    ep = int(np.prod([mesh.shape[a] for a in model_axes]))
    E_loc = E // ep

    x_spec = P(batch_axes or None, None, None)
    p_spec = {
        "router": P(),
        "w_gate": P(model_axes, None, None),
        "w_in": P(model_axes, None, None),
        "w_out": P(model_axes, None, None),
    }

    def local(params_loc, x_loc):
        B, S, D = x_loc.shape
        T = B * S
        K = cfg.experts_per_token
        C = max(int(np.ceil(T * K / E * cfg.capacity_factor)), 1)
        xt = x_loc.reshape(T, D)

        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params_loc["router"])
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, K)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        ep_rank = jax.lax.axis_index(model_axes[0]) if len(model_axes) == 1 \
            else jax.lax.axis_index(model_axes)
        lo = ep_rank * E_loc
        flat_e = top_e.reshape(-1)
        flat_p = top_p.reshape(-1)
        mine = (flat_e >= lo) & (flat_e < lo + E_loc)
        loc_e = jnp.clip(flat_e - lo, 0, E_loc - 1)

        # position within local expert (among my assignments only)
        key = jnp.where(mine, loc_e, E_loc)              # E_loc = discard bin
        order = jnp.argsort(key, stable=True)
        sorted_key = key[order]
        counts = jnp.bincount(key, length=E_loc + 1)
        starts = jnp.cumsum(counts) - counts
        rank_sorted = jnp.arange(T * K) - starts[sorted_key]
        pos = jnp.zeros(T * K, jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))

        keep = mine & (pos < C)
        safe_e = jnp.where(keep, loc_e, 0)
        safe_pos = jnp.where(keep, pos, C)

        xt_rep = jnp.repeat(xt, K, axis=0) * keep[:, None].astype(x_loc.dtype)
        buf = jnp.zeros((E_loc, C + 1, D), x_loc.dtype)
        buf = buf.at[safe_e, safe_pos].add(xt_rep)[:, :C]

        g = jnp.einsum("ecd,edf->ecf", buf, params_loc["w_gate"])
        h = jnp.einsum("ecd,edf->ecf", buf, params_loc["w_in"])
        act = jax.nn.silu(g.astype(jnp.float32)).astype(x_loc.dtype) * h
        y = jnp.einsum("ecf,efd->ecd", act, params_loc["w_out"])

        tok_ids = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
        token_of = jnp.full((E_loc, C + 1), T, jnp.int32).at[safe_e, safe_pos].set(tok_ids)
        w_of = jnp.zeros((E_loc, C + 1), jnp.float32).at[safe_e, safe_pos].set(flat_p * keep)
        weighted = y * w_of[:, :C, None].astype(y.dtype)
        out = jnp.zeros((T + 1, D), x_loc.dtype).at[token_of[:, :C]].add(weighted)
        out = out[:T]
        # the ONLY collective: combine partial expert outputs across the
        # expert(model) axis
        out = jax.lax.psum(out, model_axes)
        return out.reshape(B, S, D)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(p_spec, x_spec), out_specs=x_spec,
                   check_vma=False)
    return fn(params, x)


def ep_applicable(cfg, plan) -> bool:
    if plan is None or not cfg.num_experts:
        return False
    axes = plan.tensor_axes
    if not axes:
        return False
    ep = int(np.prod([plan.mesh.shape[a] for a in axes]))
    return cfg.num_experts % ep == 0 and cfg.num_experts >= ep


def load_balance_loss(params, x, cfg):
    """Auxiliary load-balancing loss (Shazeer-style): E * sum(f_e * p_e)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    xt = x.reshape(-1, D)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, top_e = jax.lax.top_k(probs, K)
    f = jnp.mean(jax.nn.one_hot(top_e, E).sum(axis=1), axis=0)   # fraction routed
    p = jnp.mean(probs, axis=0)
    return E * jnp.sum(f * p)
