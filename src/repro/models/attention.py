"""GQA attention: full / sliding-window / local-global, training and decode.

Two XLA execution strategies (the Pallas flash kernel in repro.kernels is the
TPU-native third, validated in interpret mode):

* ``naive``   — materialize (S, S) scores; fine for smoke tests.
* ``chunked`` — lax.scan over query chunks with online softmax
  (flash-attention recurrence in pure jnp); bounds activation memory to
  O(chunk · S) per head and is the oracle for the Pallas kernel.

Decode: one query token against a KV cache laid out (B, S_max, Hkv, hd).
Sliding-window layers keep a ring-buffer cache of size window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

NEG_INF = -1e30


# ------------------------------------------------------------------ params
def init_attention(key, cfg):
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = L.dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(k1, (d, h * hd), dt),
        "wk": L.dense_init(k2, (d, hkv * hd), dt),
        "wv": L.dense_init(k3, (d, hkv * hd), dt),
        "wo": L.dense_init(k4, (h * hd, d), dt),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def _mask(q_pos, k_pos, window):
    """causal (+ optional sliding window) mask: True = attend."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def _project_qkv(params, x, positions, cfg, window):
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = _split_heads(jnp.einsum("bsd,dk->bsk", x, params["wq"]), h, hd)
    k = _split_heads(jnp.einsum("bsd,dk->bsk", x, params["wk"]), hkv, hd)
    v = _split_heads(jnp.einsum("bsd,dk->bsk", x, params["wv"]), hkv, hd)
    if cfg.rope_mode == "standard":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_mode == "mrope":
        q = L.apply_mrope(q, positions, cfg.rope_theta)
        k = L.apply_mrope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_train(params, x, positions, cfg, *, window=None, impl="chunked"):
    """Self-attention over a full sequence. x: (B,S,D); positions (B,S) or (3,B,S)."""
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(params, x, positions, cfg, window)
    n_rep = h // hkv
    scale = 1.0 / np.sqrt(hd)
    B, S = x.shape[0], x.shape[1]
    qpos = jnp.arange(S)

    if impl == "naive" or S <= cfg.attn_chunk:
        kk = _repeat_kv(k, n_rep)
        vv = _repeat_kv(v, n_rep)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * scale
        mask = _mask(qpos, qpos, window)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    else:
        out = _chunked_attention(q, k, v, n_rep, scale, cfg.attn_chunk, window)

    out = out.reshape(B, S, h * hd)
    return jnp.einsum("bsk,kd->bsd", out, params["wo"])


def _chunked_attention(q, k, v, n_rep, scale, chunk, window):
    """Online-softmax attention, scanning over query chunks (flash-style).

    For sliding-window layers each query chunk only reads the KV slice
    [chunk_start - window, chunk_end) — sub-quadratic work.
    """
    B, S, H, hd = q.shape
    nq = S // chunk
    kk = _repeat_kv(k, n_rep)          # (B, S, H, hd)
    vv = _repeat_kv(v, n_rep)
    kpos_all = jnp.arange(S)

    if window is not None:
        span = int(min(S, chunk * int(np.ceil(window / chunk)) + chunk))
    else:
        span = None

    @jax.checkpoint
    def one_chunk(qi, q_chunk):
        # rematted: per-chunk scores/probs are recomputed in the backward
        # pass — peak live memory stays O(one chunk), not O(all chunks)
        q_start = qi * chunk
        qpos = q_start + jnp.arange(chunk)
        if span is None:
            keys, vals, kpos = kk, vv, kpos_all
        else:
            k_start = jnp.maximum(q_start + chunk - span, 0)
            keys = jax.lax.dynamic_slice_in_dim(kk, k_start, span, axis=1)
            vals = jax.lax.dynamic_slice_in_dim(vv, k_start, span, axis=1)
            kpos = k_start + jnp.arange(span)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_chunk, keys).astype(jnp.float32) * scale
        m = _mask(qpos, kpos, window)
        s = jnp.where(m[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", p, vals)

    q_chunks = q.reshape(B, nq, chunk, H, hd).swapaxes(0, 1)   # (nq,B,chunk,H,hd)
    out = jax.lax.map(lambda args: one_chunk(*args),
                      (jnp.arange(nq), q_chunks))
    return out.swapaxes(0, 1).reshape(B, S, H, hd)


# ------------------------------------------------------------------- decode
def init_kv_cache(cfg, batch, max_len, window=None):
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    size = min(max_len, window) if window is not None else max_len
    dt = L.dtype_of(cfg)
    return {
        "k": jnp.zeros((batch, size, hkv, hd), dt),
        "v": jnp.zeros((batch, size, hkv, hd), dt),
    }


def attention_decode(params, x, cache, index, cfg, *, window=None):
    """One-token decode. x: (B,1,D); cache k/v: (B,Sc,Hkv,hd); index: scalar
    current absolute position. Returns (out, new_cache)."""
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    B = x.shape[0]
    positions = jnp.full((B, 1), index, jnp.int32)
    if cfg.rope_mode == "mrope":
        positions = jnp.broadcast_to(positions, (3, B, 1))
    q, k_new, v_new = _project_qkv(params, x, positions, cfg, window)
    Sc = cache["k"].shape[1]
    slot = index % Sc if window is not None else index      # ring buffer
    k = cache["k"].at[:, slot].set(k_new[:, 0])
    v = cache["v"].at[:, slot].set(v_new[:, 0])
    n_rep = h // hkv
    kk = _repeat_kv(k, n_rep)
    vv = _repeat_kv(v, n_rep)
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * scale
    kpos = jnp.arange(Sc)
    if window is not None:
        # ring buffer: valid entries are those written within the last
        # `window` steps; absolute position of slot j is reconstructed below.
        age = (slot - kpos) % Sc
        valid = age < jnp.minimum(index + 1, Sc)
    else:
        valid = kpos <= index
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv).reshape(B, 1, h * hd)
    out = jnp.einsum("bsk,kd->bsd", out, params["wo"])
    return out, {"k": k, "v": v}
