"""GQA attention: full / sliding-window / local-global, training and decode.

Two XLA execution strategies (the Pallas flash kernel in repro.kernels is the
TPU-native third, validated in interpret mode):

* ``naive``   — materialize (S, S) scores; fine for smoke tests.
* ``chunked`` — lax.scan over query chunks with online softmax
  (flash-attention recurrence in pure jnp); bounds activation memory to
  O(chunk · S) per head and is the oracle for the Pallas kernel.

Decode: one query token against a KV cache laid out (B, S_max, Hkv, hd).
Sliding-window layers keep a ring-buffer cache of size window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quantize import dequantize_kv, quantize_kv
from repro.models import layers as L

NEG_INF = -1e30


def _quantize_pair(k, v):
    """Quantize a K/V write for an int8 pool/cache: per-vector nearest-even
    rounding, so every path (dense cache, paged prefill/decode/verify,
    re-prefill after preemption) stores bit-identical values for the same
    input vector — the invariant the engine's replay-equality tests rely on."""
    qk, sk = quantize_kv(k)
    qv, sv = quantize_kv(v)
    return qk, sk, qv, sv


# ------------------------------------------------------------------ params
def init_attention(key, cfg):
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = L.dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(k1, (d, h * hd), dt),
        "wk": L.dense_init(k2, (d, hkv * hd), dt),
        "wv": L.dense_init(k3, (d, hkv * hd), dt),
        "wo": L.dense_init(k4, (h * hd, d), dt),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def _mask(q_pos, k_pos, window):
    """causal (+ optional sliding window) mask: True = attend."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def _project_qkv(params, x, positions, cfg, window):
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = _split_heads(jnp.einsum("bsd,dk->bsk", x, params["wq"]), h, hd)
    k = _split_heads(jnp.einsum("bsd,dk->bsk", x, params["wk"]), hkv, hd)
    v = _split_heads(jnp.einsum("bsd,dk->bsk", x, params["wv"]), hkv, hd)
    if cfg.rope_mode == "standard":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_mode == "mrope":
        q = L.apply_mrope(q, positions, cfg.rope_theta)
        k = L.apply_mrope(k, positions, cfg.rope_theta)
    return q, k, v


def _attend_full(q, k, v, n_rep, scale, chunk, window):
    """Full-sequence causal(+window) attention, dispatching naive/chunked
    (chunked needs S % chunk == 0; odd lengths take the naive path)."""
    S = q.shape[1]
    if S <= chunk or S % chunk != 0:
        kk = _repeat_kv(k, n_rep)
        vv = _repeat_kv(v, n_rep)
        qpos = jnp.arange(S)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * scale
        mask = _mask(qpos, qpos, window)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    return _chunked_attention(q, k, v, n_rep, scale, chunk, window)


def attention_train(params, x, positions, cfg, *, window=None, impl="chunked"):
    """Self-attention over a full sequence. x: (B,S,D); positions (B,S) or (3,B,S)."""
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(params, x, positions, cfg, window)
    n_rep = h // hkv
    scale = 1.0 / np.sqrt(hd)
    B, S = x.shape[0], x.shape[1]

    chunk = S if impl == "naive" else cfg.attn_chunk
    out = _attend_full(q, k, v, n_rep, scale, chunk, window)

    out = out.reshape(B, S, h * hd)
    return jnp.einsum("bsk,kd->bsd", out, params["wo"])


def _chunked_attention(q, k, v, n_rep, scale, chunk, window):
    """Online-softmax attention, scanning over query chunks (flash-style).

    For sliding-window layers each query chunk only reads the KV slice
    [chunk_start - window, chunk_end) — sub-quadratic work.
    """
    B, S, H, hd = q.shape
    nq = S // chunk
    kk = _repeat_kv(k, n_rep)          # (B, S, H, hd)
    vv = _repeat_kv(v, n_rep)
    kpos_all = jnp.arange(S)

    if window is not None:
        span = int(min(S, chunk * int(np.ceil(window / chunk)) + chunk))
    else:
        span = None

    @jax.checkpoint
    def one_chunk(qi, q_chunk):
        # rematted: per-chunk scores/probs are recomputed in the backward
        # pass — peak live memory stays O(one chunk), not O(all chunks)
        q_start = qi * chunk
        qpos = q_start + jnp.arange(chunk)
        if span is None:
            keys, vals, kpos = kk, vv, kpos_all
        else:
            k_start = jnp.maximum(q_start + chunk - span, 0)
            keys = jax.lax.dynamic_slice_in_dim(kk, k_start, span, axis=1)
            vals = jax.lax.dynamic_slice_in_dim(vv, k_start, span, axis=1)
            kpos = k_start + jnp.arange(span)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_chunk, keys).astype(jnp.float32) * scale
        m = _mask(qpos, kpos, window)
        s = jnp.where(m[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", p, vals)

    q_chunks = q.reshape(B, nq, chunk, H, hd).swapaxes(0, 1)   # (nq,B,chunk,H,hd)
    out = jax.lax.map(lambda args: one_chunk(*args),
                      (jnp.arange(nq), q_chunks))
    return out.swapaxes(0, 1).reshape(B, S, H, hd)


# ------------------------------------------------------------------- decode
def init_kv_cache(cfg, batch, max_len, window=None, kv_quant=None):
    from repro.models.state_providers import alloc_kv_pool
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    size = min(max_len, window) if window is not None else max_len
    return alloc_kv_pool((batch, size), hkv, hd, L.dtype_of(cfg), kv_quant)


def attention_prefill(params, x, cache, cfg, *, window=None):
    """Batched prefill: full-sequence causal attention AND cache fill in ONE
    pass (vs. the O(S) sequential decode loop). x: (B,S,D) starting at
    position 0. Writes K/V into the decode cache (ring-aware for
    sliding-window layers: only the last `window` positions land, at their
    ring slots). Returns (out (B,S,D), new_cache)."""
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.rope_mode == "mrope":
        positions = jnp.broadcast_to(positions[None], (3, B, S))
    q, k, v = _project_qkv(params, x, positions, cfg, window)
    quant = "k_scale" in cache
    if quant:
        # attend the ROUND-TRIPPED values: the paged prefill reads its keys
        # back from the int8 pool, so the dense reference must see the same
        # quantization error for token-level parity
        qk, sk, qv, sv = _quantize_pair(k, v)
        k = dequantize_kv(qk, sk).astype(k.dtype)
        v = dequantize_kv(qv, sv).astype(v.dtype)
    n_rep = h // hkv
    scale = 1.0 / np.sqrt(hd)
    out = _attend_full(q, k, v, n_rep, scale, cfg.attn_chunk, window)
    out = out.reshape(B, S, h * hd)
    out = jnp.einsum("bsk,kd->bsd", out, params["wo"])

    Sc = cache["k"].shape[1]
    keep = min(S, Sc)                       # ring slots are unique for the
    slots = (jnp.arange(S - keep, S)) % Sc  # last `keep` positions only
    if quant:
        new_cache = {
            "k": cache["k"].at[:, slots].set(qk[:, S - keep:]),
            "v": cache["v"].at[:, slots].set(qv[:, S - keep:]),
            "k_scale": cache["k_scale"].at[:, slots].set(sk[:, S - keep:]),
            "v_scale": cache["v_scale"].at[:, slots].set(sv[:, S - keep:]),
        }
    else:
        new_cache = {
            "k": cache["k"].at[:, slots].set(k[:, S - keep:]),
            "v": cache["v"].at[:, slots].set(v[:, S - keep:]),
        }
    return out, new_cache


# ------------------------------------------------------------ paged decode
def paged_write(kv, k_new, v_new, block_tables, positions, active, *,
                ring_pages=None):
    """Scatter one token's K/V per sequence into the block pool.

    kv: {"k","v"}: (N, bs, Hkv, hd); k_new/v_new: (B, Hkv, hd);
    block_tables: (B, P); positions: (B,) absolute token position;
    active: (B,) bool — inactive rows are dropped (OOB block id).
    ring_pages: sliding-window layers write page (pos // bs) % ring_pages
    so the sequence never touches more than ring_pages blocks. An int8 pool
    (with "k_scale"/"v_scale") quantizes on write, scattering the scales at
    the same (block, offset)."""
    N, bs = kv["k"].shape[0], kv["k"].shape[1]
    B = positions.shape[0]
    pages = positions // bs
    if ring_pages is not None:
        pages = pages % ring_pages
    bids = block_tables[jnp.arange(B), pages]
    bids = jnp.where(active, bids, N)       # OOB => mode="drop"
    offs = positions % bs
    if "k_scale" in kv:
        qk, sk, qv, sv = _quantize_pair(k_new, v_new)
        return {
            "k": kv["k"].at[bids, offs].set(qk, mode="drop"),
            "v": kv["v"].at[bids, offs].set(qv, mode="drop"),
            "k_scale": kv["k_scale"].at[bids, offs].set(sk, mode="drop"),
            "v_scale": kv["v_scale"].at[bids, offs].set(sv, mode="drop"),
        }
    return {
        "k": kv["k"].at[bids, offs].set(k_new, mode="drop"),
        "v": kv["v"].at[bids, offs].set(v_new, mode="drop"),
    }


def attention_decode_paged(params, x, kv, block_tables, positions, attn_lens,
                           cfg, *, impl="ref", interpret=None, window=None,
                           ring_pages=None):
    """One-token decode against a paged KV pool. x: (B,1,D); kv k/v pools
    (N, bs, Hkv, hd); block_tables (B, P); positions (B,) absolute position of
    the incoming token; attn_lens (B,) tokens to attend over INCLUDING the new
    one (0 marks an inactive slot — its write is dropped and its output is
    garbage the engine ignores). window/ring_pages switch sliding-window
    layers to the ring layout (write modulo the ring, attend the last
    `window` positions). Returns (out (B,1,D), new kv)."""
    from repro.kernels.paged_attention import paged_attention, paged_attention_ref
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    B = x.shape[0]
    pos_b1 = positions[:, None]
    if cfg.rope_mode == "mrope":
        pos_b1 = jnp.broadcast_to(pos_b1[None], (3, B, 1))
    q, k_new, v_new = _project_qkv(params, x, pos_b1, cfg, window)
    kv = paged_write(kv, k_new[:, 0], v_new[:, 0], block_tables, positions,
                     attn_lens > 0, ring_pages=ring_pages)
    scales = dict(k_scale=kv.get("k_scale"), v_scale=kv.get("v_scale"))
    if impl == "kernel":
        out = paged_attention(q[:, 0], kv["k"], kv["v"], block_tables,
                              attn_lens, window=window, positions=positions,
                              ring_pages=ring_pages, interpret=interpret,
                              **scales)
    else:
        out = paged_attention_ref(q[:, 0], kv["k"], kv["v"], block_tables,
                                  attn_lens, window=window,
                                  positions=positions, ring_pages=ring_pages,
                                  **scales)
    out = out.reshape(B, 1, h * hd)
    return jnp.einsum("bsk,kd->bsd", out, params["wo"]), kv


def paged_write_multi(kv, k_new, v_new, block_tables, positions, valid, *,
                      ring_pages=None):
    """Scatter K draft tokens' K/V per sequence into the block pool.

    kv: {"k","v"}: (N, bs, Hkv, hd); k_new/v_new: (B, K, Hkv, hd);
    block_tables: (B, P); positions: (B, K) absolute token positions;
    valid: (B, K) bool — invalid (rejected-horizon or inactive) writes are
    dropped (OOB block id) so pool contents stay canonical. ring_pages:
    sliding-window layers write page (pos // bs) % ring_pages. Int8 pools
    quantize on write as in :func:`paged_write`."""
    N, bs = kv["k"].shape[0], kv["k"].shape[1]
    pages = positions // bs
    if ring_pages is not None:
        pages = pages % ring_pages
    bids = jnp.take_along_axis(block_tables, pages, axis=1)       # (B, K)
    bids = jnp.where(valid, bids, N)        # OOB => mode="drop"
    offs = positions % bs
    if "k_scale" in kv:
        qk, sk, qv, sv = _quantize_pair(k_new, v_new)
        return {
            "k": kv["k"].at[bids, offs].set(qk, mode="drop"),
            "v": kv["v"].at[bids, offs].set(qv, mode="drop"),
            "k_scale": kv["k_scale"].at[bids, offs].set(sk, mode="drop"),
            "v_scale": kv["v_scale"].at[bids, offs].set(sv, mode="drop"),
        }
    return {
        "k": kv["k"].at[bids, offs].set(k_new, mode="drop"),
        "v": kv["v"].at[bids, offs].set(v_new, mode="drop"),
    }


def attention_verify_paged(params, x, kv, block_tables, base, qlims, cfg, *,
                           impl="ref", interpret=None, window=None,
                           ring_pages=None):
    """Multi-query speculative verify against a paged KV pool. x: (B,K,D) —
    K draft tokens per sequence, draft j at absolute position base[b] + j.
    qlims (B,): number of draft positions whose K/V may be written this step
    (0 marks an inactive slot); queries at or past qlims produce garbage the
    engine discards, and their writes are dropped so rejected-horizon KV
    never lands in the pool. window/ring_pages switch sliding-window layers
    to the ring layout — the ring must be sized with `draft = K - 1` slack
    (see state_providers.ring_pages). Returns (out (B,K,D), new kv)."""
    from repro.kernels.paged_attention import (paged_attention_verify,
                                               paged_attention_verify_ref)
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    B, K = x.shape[0], x.shape[1]
    positions = base[:, None] + jnp.arange(K)[None, :]            # (B, K)
    pos_in = positions
    if cfg.rope_mode == "mrope":
        pos_in = jnp.broadcast_to(pos_in[None], (3, B, K))
    q, k_new, v_new = _project_qkv(params, x, pos_in, cfg, window)
    write = jnp.arange(K)[None, :] < qlims[:, None]               # (B, K)
    kv = paged_write_multi(kv, k_new, v_new, block_tables, positions, write,
                           ring_pages=ring_pages)
    attn_lens = jnp.where(qlims > 0, base + K, 0)
    newest = attn_lens - 1
    scales = dict(k_scale=kv.get("k_scale"), v_scale=kv.get("v_scale"))
    if impl == "kernel":
        out = paged_attention_verify(
            q, kv["k"], kv["v"], block_tables, attn_lens, window=window,
            positions=newest, ring_pages=ring_pages, interpret=interpret,
            **scales)
    else:
        out = paged_attention_verify_ref(
            q, kv["k"], kv["v"], block_tables, attn_lens, window=window,
            positions=newest, ring_pages=ring_pages, **scales)
    out = out.reshape(B, K, h * hd)
    return jnp.einsum("bsk,kd->bsd", out, params["wo"]), kv


def attention_prefill_paged(params, x, kv, table_rows, starts, valids, cfg):
    """Segment-masked packed prefill against the paged pool. x: (G,C,D) —
    one prompt chunk per segment, segment g starting at absolute position
    `starts[g]`, of which the first `valids[g]` tokens are real (the rest
    padding; `valids[g] == 0` marks an all-padding segment whose writes are
    dropped and whose output rows the caller ignores). Segments own disjoint
    block tables (shared prefix blocks are read-only and not written here),
    so the combined scatter plus per-segment gathers are race-free. Writes
    each segment's chunk K/V into the pool, then attends causally over each
    segment's own prefix gathered via its table row. Returns
    (out (G,C,D), new kv)."""
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    G, C = x.shape[0], x.shape[1]
    pos = starts[:, None] + jnp.arange(C)[None, :]                # (G, C)
    positions = pos
    if cfg.rope_mode == "mrope":
        positions = jnp.broadcast_to(positions[None], (3, G, C))
    q, k, v = _project_qkv(params, x, positions, cfg, None)

    N, bs = kv["k"].shape[0], kv["k"].shape[1]
    valid = jnp.arange(C)[None, :] < valids[:, None]              # (G, C)
    bids = jnp.where(
        valid, jnp.take_along_axis(table_rows, pos // bs, axis=1), N)
    offs = pos % bs
    if "k_scale" in kv:
        qk, sk, qv, sv = _quantize_pair(k, v)
        kv = {
            "k": kv["k"].at[bids, offs].set(qk, mode="drop"),
            "v": kv["v"].at[bids, offs].set(qv, mode="drop"),
            "k_scale": kv["k_scale"].at[bids, offs].set(sk, mode="drop"),
            "v_scale": kv["v_scale"].at[bids, offs].set(sv, mode="drop"),
        }
    else:
        kv = {
            "k": kv["k"].at[bids, offs].set(k, mode="drop"),
            "v": kv["v"].at[bids, offs].set(v, mode="drop"),
        }

    # the gather-back below reads the (possibly quantized) pool contents, so
    # every query attends the same values the decode kernel will later see
    from repro.kernels.paged_attention.ref import _gather_pool
    P = table_rows.shape[1]
    n_rep = h // hkv
    if "k_scale" in kv:
        kk = _repeat_kv(
            _gather_pool(kv["k"], kv["k_scale"], table_rows, P * bs), n_rep)
        vv = _repeat_kv(
            _gather_pool(kv["v"], kv["v_scale"], table_rows, P * bs), n_rep)
    else:
        kk = _repeat_kv(kv["k"][table_rows].reshape(G, P * bs, hkv, hd), n_rep)
        vv = _repeat_kv(kv["v"][table_rows].reshape(G, P * bs, hkv, hd), n_rep)
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * scale
    mask = jnp.arange(P * bs)[None, None, :] <= pos[:, :, None]   # (G, C, P*bs)
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv).reshape(G, C, h * hd)
    return jnp.einsum("bsk,kd->bsd", out, params["wo"]), kv


def attention_prefill_ring(params, x, kv, table_rows, starts, valids, cfg,
                           *, window, ring_pages):
    """Segment-masked packed prefill against a RING-paged pool. x: (G,C,D) —
    one chunk per segment starting at `starts[g]`, first `valids[g]` tokens
    real. Each segment owns only `ring_pages` blocks; its position p lives
    at `table_rows[g, (p // bs) % ring_pages]`, offset `p % bs`.

    Unlike the full-attention path (write, then gather everything back),
    the pre-chunk ring content is gathered BEFORE the chunk's writes: on
    wraparound the chunk overwrites pages that early queries still need, so
    read-then-write is required for correctness. Each query t attends the
    union of {its segment's pre-chunk ring keys} ∪ {its segment's chunk},
    masked to its window (t - window, t]. Returns (out (G,C,D), new kv)."""
    from repro.kernels.paged_attention.ref import (_gather_pool,
                                                   ring_key_positions)
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    G, C = x.shape[0], x.shape[1]
    pos = starts[:, None] + jnp.arange(C)[None, :]                # (G, C)
    positions = pos
    if cfg.rope_mode == "mrope":
        positions = jnp.broadcast_to(positions[None], (3, G, C))
    q, k, v = _project_qkv(params, x, positions, cfg, window)
    quant = "k_scale" in kv
    if quant:
        # chunk keys are attended from registers (never re-read from the
        # pool), so round-trip them explicitly for parity with the decode
        # steps that WILL read them back quantized
        qk, sk, qv, sv = _quantize_pair(k, v)
        k = dequantize_kv(qk, sk).astype(k.dtype)
        v = dequantize_kv(qv, sv).astype(v.dtype)

    N, bs = kv["k"].shape[0], kv["k"].shape[1]
    R = ring_pages

    # 1) gather each segment's ring as of starts-1 (before this chunk's
    # writes)
    ring_rows = table_rows[:, :R]                                 # (G, R)
    if quant:
        old_k = _gather_pool(kv["k"], kv["k_scale"], ring_rows, R * bs)
        old_v = _gather_pool(kv["v"], kv["v_scale"], ring_rows, R * bs)
    else:
        old_k = kv["k"][ring_rows].reshape(G, R * bs, hkv, hd)
        old_v = kv["v"][ring_rows].reshape(G, R * bs, hkv, hd)
    old_pos = ring_key_positions(starts - 1, R, bs)               # (G, R*bs)
    # entries the pre-chunk ring never held: pages < 0 entirely, and the
    # current page's offsets past (start-1) % bs (previous-lap leftovers,
    # reconstructed as > start-1)
    old_ok = (old_pos >= 0) & (old_pos <= (starts - 1)[:, None])

    # 2) write the chunk's K/V at their ring slots. Padding rows are
    # dropped, and so is any position lapped by a LATER valid position in
    # this same chunk (C can exceed the ring capacity R*bs): `.at[].set`
    # leaves duplicate-index order undefined, so only each (slot, offset)'s
    # newest lap may write. Skipped positions are > R*bs > window older
    # than the chunk's last token — nothing downstream can attend them.
    last_valid = (starts + valids - 1)[:, None]                   # (G, 1)
    write = ((jnp.arange(C)[None, :] < valids[:, None])
             & (pos > last_valid - R * bs))
    bids = jnp.where(
        write, jnp.take_along_axis(table_rows, (pos // bs) % R, axis=1), N)
    offs = pos % bs
    if quant:
        kv = {
            "k": kv["k"].at[bids, offs].set(qk, mode="drop"),
            "v": kv["v"].at[bids, offs].set(qv, mode="drop"),
            "k_scale": kv["k_scale"].at[bids, offs].set(sk, mode="drop"),
            "v_scale": kv["v_scale"].at[bids, offs].set(sv, mode="drop"),
        }
    else:
        kv = {
            "k": kv["k"].at[bids, offs].set(k, mode="drop"),
            "v": kv["v"].at[bids, offs].set(v, mode="drop"),
        }

    # 3) attend: keys = each segment's pre-chunk ring ∪ its own chunk
    n_rep = h // hkv
    kk = _repeat_kv(jnp.concatenate([old_k, k], axis=1), n_rep)
    vv = _repeat_kv(jnp.concatenate([old_v, v], axis=1), n_rep)
    kpos = jnp.concatenate([old_pos, pos], axis=1)                # (G, R*bs+C)
    kok = jnp.concatenate([old_ok, jnp.ones((G, C), bool)], axis=1)
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * scale
    mask = (kok[:, None, :]
            & (kpos[:, None, :] <= pos[:, :, None])
            & (kpos[:, None, :] > pos[:, :, None] - window))      # (G, C, K)
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv).reshape(G, C, h * hd)
    return jnp.einsum("bsk,kd->bsd", out, params["wo"]), kv


def attention_decode(params, x, cache, index, cfg, *, window=None):
    """One-token decode. x: (B,1,D); cache k/v: (B,Sc,Hkv,hd); index: scalar
    current absolute position. Returns (out, new_cache)."""
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    B = x.shape[0]
    positions = jnp.full((B, 1), index, jnp.int32)
    if cfg.rope_mode == "mrope":
        positions = jnp.broadcast_to(positions, (3, B, 1))
    q, k_new, v_new = _project_qkv(params, x, positions, cfg, window)
    Sc = cache["k"].shape[1]
    slot = index % Sc if window is not None else index      # ring buffer
    if "k_scale" in cache:
        qk, sk, qv, sv = _quantize_pair(k_new[:, 0], v_new[:, 0])
        new_cache = {
            "k": cache["k"].at[:, slot].set(qk),
            "v": cache["v"].at[:, slot].set(qv),
            "k_scale": cache["k_scale"].at[:, slot].set(sk),
            "v_scale": cache["v_scale"].at[:, slot].set(sv),
        }
        k = dequantize_kv(new_cache["k"], new_cache["k_scale"]).astype(x.dtype)
        v = dequantize_kv(new_cache["v"], new_cache["v_scale"]).astype(x.dtype)
    else:
        k = cache["k"].at[:, slot].set(k_new[:, 0])
        v = cache["v"].at[:, slot].set(v_new[:, 0])
        new_cache = {"k": k, "v": v}
    n_rep = h // hkv
    kk = _repeat_kv(k, n_rep)
    vv = _repeat_kv(v, n_rep)
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * scale
    kpos = jnp.arange(Sc)
    if window is not None:
        # ring buffer: valid entries are those written within the last
        # `window` steps; absolute position of slot j is reconstructed below.
        age = (slot - kpos) % Sc
        valid = age < jnp.minimum(index + 1, Sc)
    else:
        valid = kpos <= index
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv).reshape(B, 1, h * hd)
    out = jnp.einsum("bsk,kd->bsd", out, params["wo"])
    return out, new_cache
