"""Convolution algorithms from survey §4.3, implemented and cross-validated:
direct, im2col (Toeplitz/GEMM), FFT, and Winograd F(2x2, 3x3).

These are the survey's Table-6 subjects as *runnable* JAX code (the W-D
models live in core/workdepth.py). All operate on NCHW tensors with VALID
padding, matching Eq. 2 of the paper; each is tested against `conv_direct`
in tests/test_conv_algorithms.py, including the paper's numerics claim that
Winograd loses accuracy relative to direct computation as magnitudes grow.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def conv_direct(x, w):
    """Eq. 2 verbatim via lax.conv. x: (N, C, H, W); w: (K, C, Ky, Kx)."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def conv_im2col(x, w):
    """Toeplitz unrolling + one GEMM (§4.3 'processor-friendly' method)."""
    N, C, H, W = x.shape
    K, C2, Ky, Kx = w.shape
    Ho, Wo = H - Ky + 1, W - Kx + 1
    # patches: (N, Ho, Wo, C*Ky*Kx)
    patches = jnp.stack([
        x[:, :, dy:dy + Ho, dx:dx + Wo]
        for dy in range(Ky) for dx in range(Kx)
    ], axis=-1)                                    # (N, C, Ho, Wo, Ky*Kx)
    patches = patches.transpose(0, 2, 3, 1, 4).reshape(N * Ho * Wo, C * Ky * Kx)
    kernel = w.reshape(K, C * Ky * Kx).T           # (C*Ky*Kx, K)
    out = patches @ kernel                         # THE GEMM
    return out.reshape(N, Ho, Wo, K).transpose(0, 3, 1, 2)


def conv_fft(x, w):
    """Fourier-domain convolution (§4.3): y = IFFT(Σ_c FFT(x_c) ∘ FFT(w_c)).

    Correlation (as in Eq. 2) = convolution with a flipped kernel, handled by
    conjugation-free index flip before the transform.
    """
    N, C, H, W = x.shape
    K, _, Ky, Kx = w.shape
    Ho, Wo = H - Ky + 1, W - Kx + 1
    wf = w[:, :, ::-1, ::-1]                       # correlation -> convolution
    X = jnp.fft.rfft2(x, s=(H, W))                 # (N, C, H, W//2+1)
    Wt = jnp.fft.rfft2(wf, s=(H, W))               # (K, C, H, W//2+1)
    Y = jnp.einsum("nchw,kchw->nkhw", X, Wt)       # sum over channels
    y = jnp.fft.irfft2(Y, s=(H, W))                # full conv result
    return y[:, :, Ky - 1:Ky - 1 + Ho, Kx - 1:Kx - 1 + Wo]


# Winograd F(2x2, 3x3) transform matrices [Lavin & Gray 2016, §4.3]
_B = np.array([[1, 0, 0, 0], [0, 1, -1, 1], [-1, 1, 1, 0], [0, 0, 0, -1]],
              np.float32)
_G = np.array([[1, 0, 0], [0.5, 0.5, 0.5], [0.5, -0.5, 0.5], [0, 0, 1]],
              np.float32)
_A = np.array([[1, 0], [1, 1], [1, -1], [0, -1]], np.float32)


def conv_winograd(x, w):
    """Winograd minimal filtering F(2x2, 3x3) (§4.3):
       Y = A^T [ Σ_c (G g G^T) ∘ (B^T d B) ] A  per 4x4 tile."""
    N, C, H, W = x.shape
    K, _, Ky, Kx = w.shape
    assert (Ky, Kx) == (3, 3), "Winograd path is for 3x3 kernels (§4.3)"
    Ho, Wo = H - 2, W - 2
    m = 2
    tiles_y, tiles_x = Ho // m, Wo // m
    B, G, A = (jnp.asarray(M) for M in (_B, _G, _A))

    # kernel transform: U = G g G^T  -> (K, C, 4, 4)
    U = jnp.einsum("ij,kcjl,ml->kcim", G, w, G)

    # input tiles: d (N, C, ty, tx, 4, 4) with stride m
    d = jnp.stack([
        jnp.stack([
            x[:, :, 2 * ty:2 * ty + 4, 2 * tx:2 * tx + 4]
            for tx in range(tiles_x)], axis=2)
        for ty in range(tiles_y)], axis=2)          # (N, C, ty, tx, 4, 4)
    V = jnp.einsum("ji,nctxjl,lm->nctxim", B, d, B)   # B^T d B

    M = jnp.einsum("kcim,nctxim->nktxim", U, V)     # elementwise ∘, Σ_c
    Y = jnp.einsum("ji,nktxjl,lm->nktxim", A, M, A)  # (N, K, ty, tx, 2, 2)
    out = Y.transpose(0, 1, 2, 4, 3, 5).reshape(N, K, tiles_y * m, tiles_x * m)
    return out[:, :, :Ho, :Wo]


ALGORITHMS = {
    "direct": conv_direct,
    "im2col": conv_im2col,
    "fft": conv_fft,
    "winograd": conv_winograd,
}
