"""Attention-free sequence mixers: RWKV6 (Finch) and Mamba2 (SSD).

Both are implemented in *chunked* form — within-chunk work is dense matmul
(MXU-friendly), across-chunk state is carried by ``lax.scan`` — the TPU-native
adaptation of the survey's "Persistent RNN" idea (§4.4): keep the recurrent
state resident (VMEM/registers there, scan carry here) instead of
round-tripping it per timestep.

Numerics: decays are handled in log space; all within-chunk decay ratios are
exp of non-positive numbers, so nothing overflows regardless of sequence
length.

Simplifications vs. the reference models (documented, structural parity kept):
  * RWKV6 token-shift uses a static learned mix (the low-rank *dynamic* mix of
    Finch is folded into the data-dependent decay LoRA, which we do keep).
  * Zamba2's shared attention concat-with-embedding projection is a plain
    shared attention block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

CHUNK = 32  # within-chunk dense block length


# =============================================================== RWKV6 (Finch)
def init_rwkv6(key, cfg):
    d = cfg.d_model
    hd = cfg.ssm_head_dim
    H = d // hd
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 12)
    lora = 32
    return {
        "mix": jnp.full((5, d), 0.5, jnp.float32),  # r,k,v,g,w token-shift mixes
        "wr": L.dense_init(ks[0], (d, d), dt),
        "wk": L.dense_init(ks[1], (d, d), dt),
        "wv": L.dense_init(ks[2], (d, d), dt),
        "wg": L.dense_init(ks[3], (d, d), dt),
        "wo": L.dense_init(ks[4], (d, d), dt),
        "w0": jnp.linspace(-6.0, -1.0, d, dtype=jnp.float32),  # decay base
        "w_lora_a": L.dense_init(ks[5], (d, lora), jnp.float32, scale=0.01),
        "w_lora_b": L.dense_init(ks[6], (lora, d), jnp.float32, scale=0.01),
        "u": jnp.zeros((d,), jnp.float32),                     # bonus
        "ln_scale": jnp.ones((H, hd), jnp.float32),            # per-head norm
        # channel mix
        "cm_mix": jnp.full((2, d), 0.5, jnp.float32),
        "cm_r": L.dense_init(ks[7], (d, d), dt),
        "cm_k": L.dense_init(ks[8], (d, cfg.d_ff), dt),
        "cm_v": L.dense_init(ks[9], (cfg.d_ff, d), dt),
    }


def _token_shift(x, prev=None):
    """Shift sequence right by one; `prev` fills slot 0 (decode/chunk carry)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


# max total log-decay magnitude representable per chunk in the factorized
# form (exp(40) ≈ 2.4e17 stays finite in f32 after one multiply)
_MAX_CHUNK_LOGDECAY = 40.0


def _wkv_chunk(r, k, v, logw, u, S0):
    """One chunk of the WKV recurrence (per batch*head, vectorized outside).

    r,k,v: (C, K) / (C, V); logw: (C, K) (non-positive, per-step clamped to
    ≥ −_MAX_CHUNK_LOGDECAY/C by the caller); u: (K,); S0: (K, V).
    Returns (y: (C, V), S1: (K, V)).

    §Perf note: the pair-decay matrix exp(lw_prev[t] − lw[i]) is FACTORIZED
    through the chunk-end reference lw_end —
        A[t,i] = (r_t·e^{lw_prev[t]−lw_end}) · (k_i·e^{lw_end−lw[i]})
    — so the whole chunk is two (C,K)·(K,C) MXU matmuls and the (C,C,K)
    decay tensor (the baseline's dominant HBM consumer, 8.9e12 B/device)
    never exists. The clamp bounds the factor exponents at ±40.
    """
    C = r.shape[0]
    lw = jnp.cumsum(logw, axis=0)                    # (C, K)
    lw_prev = lw - logw                              # lw_{t-1}, row0 = 0
    lw_end = lw[-1]
    r = r.astype(jnp.float32)                        # streamed in bf16 (§Perf)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    q_fac = r * jnp.exp(lw_prev - lw_end)            # (C, K), factors ≤ e^40
    k_fac = k * jnp.exp(lw_end - lw)                 # (C, K), factors ≤ 1
    A = q_fac @ k_fac.T                              # (C, C)
    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)
    A = jnp.where(tri, A, 0.0)
    A = A + jnp.diag(jnp.einsum("tk,k,tk->t", r, u, k))         # bonus term
    y = A @ v + jnp.einsum("tk,kv->tv", r * jnp.exp(lw_prev), S0)
    S1 = jnp.exp(lw_end)[:, None] * S0 + k_fac.T @ v
    return y, S1


def rwkv6_mix(params, x, cfg, state=None):
    """Time-mix (WKV) over a sequence. x: (B, S, D). Returns (y, new_state).

    state: {"S": (B,H,K,V), "prev": (B,1,D)} or None (zeros)."""
    B, S, D = x.shape
    hd = cfg.ssm_head_dim
    H = D // hd
    f32 = jnp.float32
    prev = None if state is None else state["prev"]
    xs = _token_shift(x, prev)
    mix = params["mix"]
    xr, xk, xv, xg, xw = ((x + mix[i] * (xs - x)).astype(x.dtype)
                          for i in range(5))
    r = jnp.einsum("bsd,de->bse", xr, params["wr"])   # bf16 until chunk-local
    k = jnp.einsum("bsd,de->bse", xk, params["wk"])
    v = jnp.einsum("bsd,de->bse", xv, params["wv"])
    g = jnp.einsum("bsd,de->bse", xg, params["wg"])
    logw = -jnp.exp(
        params["w0"]
        + jnp.einsum("bsd,dr,re->bse", xw.astype(f32), params["w_lora_a"], params["w_lora_b"])
    )  # (B,S,D) strictly negative
    # clamp per-step decay so the factorized chunk form stays in f32 range
    logw = jnp.maximum(logw, -_MAX_CHUNK_LOGDECAY / CHUNK)
    # reshape to heads: (B, S, H, hd)
    def heads(t):
        return t.reshape(B, S, H, hd)
    r, k, v, logw = heads(r), heads(k), heads(v), heads(logw)
    u = params["u"].reshape(H, hd)

    S0 = jnp.zeros((B, H, hd, hd), f32) if state is None else state["S"]

    C = min(CHUNK, S)
    nc = S // C
    rc = r.reshape(B, nc, C, H, hd)
    kc = k.reshape(B, nc, C, H, hd)
    vc = v.reshape(B, nc, C, H, hd)
    wc = logw.reshape(B, nc, C, H, hd)

    # vmapped over B (outer) and H (inner): per-chunk fn sees (C,K) etc.
    wkv = jax.vmap(
        jax.vmap(_wkv_chunk, in_axes=(1, 1, 1, 1, 0, 0), out_axes=(1, 0)),
        in_axes=(0, 0, 0, 0, None, 0), out_axes=(0, 0))

    @jax.checkpoint
    def step(S, inputs):
        # rematted: chunk internals are recomputed in the backward pass, so
        # the (nc, B, H, C, C)-sized residual stacks never hit HBM (§Perf)
        rc_, kc_, vc_, wc_ = inputs                       # (B, C, H, hd)
        y, S1 = wkv(rc_, kc_, vc_, wc_, u, S)             # y: (B, C, H, hd)
        return S1, y

    Sf, ys = jax.lax.scan(
        step, S0,
        (rc.swapaxes(0, 1), kc.swapaxes(0, 1), vc.swapaxes(0, 1), wc.swapaxes(0, 1)),
    )
    y = ys.swapaxes(0, 1).reshape(B, S, H, hd)            # (B, S, H, hd)

    # per-head group norm + gating
    mean = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 64e-5) * params["ln_scale"]
    y = y.reshape(B, S, D).astype(x.dtype) * jax.nn.silu(g.astype(f32)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, params["wo"])
    new_state = {"S": Sf, "prev": x[:, -1:]}
    return out, new_state


def rwkv6_channel_mix(params, x, cfg, state=None):
    prev = None if state is None else state
    xs = _token_shift(x, prev)
    mix = params["cm_mix"]
    xk = x + mix[0] * (xs - x)
    xr = x + mix[1] * (xs - x)
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["cm_r"]).astype(jnp.float32))
    k = jnp.einsum("bsd,df->bsf", xk, params["cm_k"]).astype(jnp.float32)
    vv = jnp.square(jax.nn.relu(k)).astype(x.dtype)
    v = jnp.einsum("bsf,fd->bsd", vv, params["cm_v"])
    return (r.astype(x.dtype) * v), x[:, -1:]


def rwkv6_state_spec(cfg):
    """Per-sequence recurrent-state layout: name -> (shape, dtype). The
    single source of truth for cache init AND the engine's per-slot slab
    provider (state_providers.RecurrentSlabProvider)."""
    hd = cfg.ssm_head_dim
    H = cfg.d_model // hd
    return {
        "S": ((H, hd, hd), jnp.float32),
        "prev": ((1, cfg.d_model), L.dtype_of(cfg)),
        "prev_cm": ((1, cfg.d_model), L.dtype_of(cfg)),
    }


def init_rwkv6_state(cfg, batch):
    return {k: jnp.zeros((batch,) + shape, dt)
            for k, (shape, dt) in rwkv6_state_spec(cfg).items()}


# ================================================================ Mamba2 (SSD)
def init_mamba2(key, cfg):
    d = cfg.d_model
    d_inner = 2 * d
    hd = cfg.ssm_head_dim
    H = d_inner // hd
    ds = cfg.ssm_state_dim
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 6)
    conv_dim = d_inner + 2 * ds
    return {
        "in_proj": L.dense_init(ks[0], (d, 2 * d_inner + 2 * ds + H), dt),
        "conv_w": (jax.random.normal(ks[1], (4, conv_dim), jnp.float32) * 0.1),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "out_proj": L.dense_init(ks[2], (d_inner, d), dt),
    }


def _causal_conv(x, w, prev=None):
    """Depthwise causal conv, kernel k. x: (B,S,C), w: (k,C), prev: (B,k-1,C)."""
    kk = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], kk - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(kk))
    return out, xp[:, -(kk - 1):]


def _ssd_chunk(xh, Bm, Cm, la, dtv, S0):
    """One SSD chunk per (batch, head).

    xh: (C, hd) inputs; Bm, Cm: (C, ds); la: (C,) cumulative log-decay within
    chunk (non-positive increments); dtv: (C,) step sizes; S0: (hd, ds).
    Returns (y: (C, hd), S1: (hd, ds)).
    """
    Cl = xh.shape[0]
    G = jnp.exp(la[:, None] - la[None, :])            # (C, C) decay i -> t
    tri = jnp.tril(jnp.ones((Cl, Cl), bool))
    M = (Cm @ Bm.T) * jnp.where(tri, G, 0.0) * dtv[None, :]
    y = M @ xh + jnp.exp(la)[:, None] * (Cm @ S0.T)   # (C, hd)
    w_end = jnp.exp(la[-1] - la) * dtv                # (C,)
    S1 = jnp.exp(la[-1]) * S0 + jnp.einsum("c,ch,cs->hs", w_end, xh, Bm)
    return y, S1


def mamba2_mix(params, x, cfg, state=None):
    """Mamba2 block. x: (B,S,D) -> (y, new_state)."""
    B, S, D = x.shape
    d_inner = 2 * D
    hd = cfg.ssm_head_dim
    H = d_inner // hd
    ds = cfg.ssm_state_dim
    f32 = jnp.float32

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xc, Bm, Cm, dt_raw = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + ds, 2 * d_inner + 2 * ds], axis=-1)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_prev = None if state is None else state["conv"]
    conv_out, conv_carry = _causal_conv(conv_in, params["conv_w"], conv_prev)
    conv_out = jax.nn.silu(conv_out.astype(f32))
    xc, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + ds], axis=-1)

    dtv = jax.nn.softplus(dt_raw.astype(f32) + params["dt_bias"])   # (B,S,H)
    A = -jnp.exp(params["A_log"])                                   # (H,) < 0
    log_a = dtv * A                                                 # (B,S,H) <= 0

    xh = xc.reshape(B, S, H, hd)
    S0 = jnp.zeros((B, H, hd, ds), f32) if state is None else state["S"]

    C = min(CHUNK, S)
    nc = S // C
    la = jnp.cumsum(log_a.reshape(B, nc, C, H), axis=2)
    xhc = xh.reshape(B, nc, C, H, hd)
    Bc = Bm.reshape(B, nc, C, ds)
    Cc = Cm.reshape(B, nc, C, ds)
    dtc = dtv.reshape(B, nc, C, H)

    # vmap over batch (outer) and head (inner); B/C mats shared across heads
    ssd = jax.vmap(  # batch
        jax.vmap(_ssd_chunk, in_axes=(1, None, None, 1, 1, 0), out_axes=(1, 0)),
        in_axes=(0, 0, 0, 0, 0, 0), out_axes=(0, 0))

    @jax.checkpoint
    def step(S, inputs):
        xh_, B_, C_, la_, dt_ = inputs
        y, S1 = ssd(xh_, B_, C_, la_, dt_, S)
        return S1, y

    Sf, ys = jax.lax.scan(
        step, S0,
        (xhc.swapaxes(0, 1), Bc.swapaxes(0, 1), Cc.swapaxes(0, 1),
         la.swapaxes(0, 1), dtc.swapaxes(0, 1)),
    )
    y = ys.swapaxes(0, 1).reshape(B, S, H, hd)
    y = y + params["D"][None, None, :, None] * xh.astype(f32)
    y = y.reshape(B, S, d_inner)
    # gated RMSNorm
    y = y * jax.nn.silu(z.astype(f32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps) * params["norm_scale"]
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), params["out_proj"])
    return out, {"S": Sf, "conv": conv_carry}


def mamba2_state_spec(cfg):
    """Per-sequence recurrent-state layout: name -> (shape, dtype)."""
    d_inner = 2 * cfg.d_model
    hd = cfg.ssm_head_dim
    H = d_inner // hd
    ds = cfg.ssm_state_dim
    conv_dim = d_inner + 2 * ds
    return {
        "S": ((H, hd, ds), jnp.float32),
        "conv": ((3, conv_dim), L.dtype_of(cfg)),
    }


def init_mamba2_state(cfg, batch):
    return {k: jnp.zeros((batch,) + shape, dt)
            for k, (shape, dt) in mamba2_state_spec(cfg).items()}
