"""Per-layer-kind sequence-state providers for the serving engine.

The paper's concurrency analysis (§3, §5) ties inference parallelism to each
operator's *state*: attention carries O(S) KV, recurrent layers carry O(1)
state, and real networks mix both. The engine therefore treats sequence
state as a pluggable policy layer — one provider per layer *state kind* —
instead of a single full-attention KV-cache special case:

  state kind   layers                         provider
  ----------   ------                         --------
  full         attn/moe_attn (full), global,  PagedKVProvider — paged block
               shared_attn                    pool, prefix caching, O(S) blocks
  ring         attn/moe_attn (sliding),       RingKVProvider — fixed
               local                          ceil(window/bs)+1 blocks per
                                              sequence, positions written
                                              modulo the ring
  rwkv         rwkv time/channel mix          RecurrentSlabProvider — per-slot
  mamba        mamba2                         O(1) state arrays, no blocks

The provider protocol splits along the host/device boundary:

  * device side  — ``init_layer_state`` (build one layer's pool/slab) and
    ``defrag_remap`` (apply a block-compaction permutation; identity for
    slabs). The jit-traced verbs — write / read-for-decode /
    read-for-prefill — are static dispatches in ``models.transformer`` /
    ``models.attention`` / ``models.ssm`` keyed by the same kind list, so
    the compiled steps never branch at runtime.
  * host side    — ``blocks_needed`` (per-sequence block cost the scheduler
    charges; the block table is shared by every layer of a sequence, so the
    per-sequence reservation is the MAX over kinds), ``state_bytes_per_slot``
    (for capacity planning / benchmarks), and ``supports_prefix_caching``
    (block aliasing is only sound for full-attention KV, whose content is a
    pure function of the token prefix).

Preemption (engine.oversub) adds a rollback protocol. Eviction is always
recompute-by-re-prefill, and each provider contributes what makes that
cheap or exact:

  * paged ``full`` KV — nothing to checkpoint: the freed blocks themselves
    carry the rollback (fully written ones are prefix-registered before the
    free, so resume aliases them back from the cached-free list).
  * ``ring`` KV — the write cursor is a pure function of the token count
    (``write_cursor``); re-prefilling the same tokens lands every position
    at the identical (page, offset), wrap-for-wrap.
  * recurrent slabs — ``supports_snapshot_resume``: ``preempt_checkpoint``
    gathers the victim's slot rows to host, ``resume_restore`` scatters
    them back, letting a pure-recurrent config skip the re-scan entirely.

``layer_kinds`` / ``superblock_layout`` live here (not in transformer.py) so
both the model dispatchers and the engine derive the SAME static kind list
from a ModelConfig without an import cycle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.quantize import KVQuantConfig
from repro.models import layers as L
from repro.models import ssm as S


# ------------------------------------------------------------- KV allocation
def alloc_kv_pool(lead_shape, hkv: int, hd: int, dtype, kv_quant=None):
    """THE allocator for K/V storage — block pools (lead (N, bs)) and dense
    caches (lead (B, S)) alike; test_repo_lint.py bans ad-hoc pool dicts
    elsewhere so every allocation stays quant-aware.

    fp32 path: {"k", "v"} of lead + (hkv, hd) in `dtype`. With `kv_quant`:
    values are int8 and {"k_scale", "v_scale"} f32 (lead + (hkv,)) carry one
    dequant scale per stored vector. Downstream attention code dispatches on
    the dict *structure* ("k_scale" in pool) — static at trace time, so no
    signature changes ripple through the jitted steps."""
    shape = tuple(lead_shape) + (hkv, hd)
    if kv_quant is None:
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    sshape = tuple(lead_shape) + (hkv,)
    # scale 1.0 matches quantize_kv on an all-zero vector, so untouched
    # slots dequantize to exactly 0.0
    return {"k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.ones(sshape, jnp.float32),
            "v_scale": jnp.ones(sshape, jnp.float32)}


# ----------------------------------------------------------- layer kind lists
def superblock_layout(cfg: ModelConfig):
    """Returns (n_superblocks, layers_per_superblock)."""
    if cfg.family == "hybrid":
        per = cfg.hybrid_ssm_per_attn + 1
        return cfg.num_layers // per, per
    if cfg.attention_type == "local_global":
        per = cfg.local_global_ratio + 1
        return cfg.num_layers // per, per
    return cfg.num_layers, 1


def layer_kinds(cfg: ModelConfig):
    """Static list of layer kinds within one superblock."""
    if cfg.family == "hybrid":
        return ["mamba"] * cfg.hybrid_ssm_per_attn + ["shared_attn"]
    if cfg.attention_type == "local_global":
        return ["local"] * cfg.local_global_ratio + ["global"]
    if cfg.family == "ssm":
        return ["rwkv"]
    if cfg.num_experts:
        return ["moe_attn"]
    return ["attn"]


def state_kind(layer_kind: str, cfg: ModelConfig) -> str:
    """Map a layer kind to its sequence-state kind."""
    if layer_kind in ("global", "shared_attn"):
        return "full"
    if layer_kind == "local":
        return "ring"
    if layer_kind in ("attn", "moe_attn"):
        return "ring" if cfg.attention_type == "sliding" else "full"
    if layer_kind == "rwkv":
        return "rwkv"
    if layer_kind == "mamba":
        return "mamba"
    raise ValueError(f"unknown layer kind {layer_kind!r}")


def state_kinds(cfg: ModelConfig):
    """Per-layer state kinds within one superblock (static)."""
    return [state_kind(k, cfg) for k in layer_kinds(cfg)]


def ring_pages(window: int, block_size: int, draft: int = 0) -> int:
    """Ring length in pages: ceil(window/bs) intact pages always cover the
    last `window` positions, +1 for the page currently being overwritten.

    ``draft`` adds speculative-decoding slack: a verify step holds K = draft
    + 1 in-flight positions, and the OLDEST draft query still needs its full
    window `(qpos - window, qpos]` resident while the ring has already
    advanced to the newest draft — so the intact span must cover
    window + draft positions back from the newest write."""
    return -(-(window + draft) // block_size) + 1


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ------------------------------------------------------------------ providers
@dataclass(frozen=True)
class _PagedPoolProvider:
    """Shared machinery of the block-pooled KV providers: pool tensor
    layout, per-slot KV bytes, and the axis-1 (block axis, after the n_sb
    stack) defrag gather. Subclasses set the block-cost policy."""
    cfg: ModelConfig
    num_blocks: int
    block_size: int
    max_blocks_per_seq: Optional[int] = None
    kv_quant: Optional[KVQuantConfig] = None

    # Preemption rollback: paged KV is rolled back by freeing blocks (and
    # re-aliasing registered ones on resume); there is no slot snapshot.
    supports_snapshot_resume = False

    def preempt_checkpoint(self, state, slot: int):
        return None

    def resume_restore(self, state, slot: int, snap):
        return state

    def init_layer_state(self):
        hkv, hd = self.cfg.num_kv_heads, self.cfg.resolved_head_dim
        return alloc_kv_pool((self.num_blocks, self.block_size), hkv, hd,
                             L.dtype_of(self.cfg), self.kv_quant)

    def _bytes_per_token(self) -> int:
        """KV bytes one stored token costs in this pool (both K and V)."""
        hkv, hd = self.cfg.num_kv_heads, self.cfg.resolved_head_dim
        if self.kv_quant is not None:
            return 2 * hkv * (hd + 4)   # int8 vector + one f32 scale per head
        return 2 * hkv * hd * np.dtype(L.dtype_of(self.cfg)).itemsize

    def state_bytes_per_slot(self, total_tokens: int) -> int:
        return (self.blocks_needed(total_tokens) * self.block_size
                * self._bytes_per_token())

    def pool_bytes_saved(self) -> int:
        """Whole-pool HBM saved by quantization vs the fp32 layout (0 when
        quantization is off) — feeds the kv_quant_bytes_saved_total gauge."""
        if self.kv_quant is None:
            return 0
        hkv, hd = self.cfg.num_kv_heads, self.cfg.resolved_head_dim
        item = np.dtype(L.dtype_of(self.cfg)).itemsize
        per_tok_fp = 2 * hkv * hd * item
        return (self.num_blocks * self.block_size
                * (per_tok_fp - self._bytes_per_token()))

    def defrag_remap(self, state, perm):
        """state leaves: (n_sb, N, bs, Hkv, hd); perm: new[i] = old[perm[i]]."""
        return jax.tree.map(lambda a: jnp.take(a, perm, axis=1), state)


@dataclass(frozen=True)
class PagedKVProvider(_PagedPoolProvider):
    """Full-attention paged KV: O(S) blocks per sequence, prefix caching."""

    kind = "full"
    supports_prefix_caching = True

    def blocks_needed(self, total_tokens: int) -> int:
        return _ceil_div(total_tokens, self.block_size)

    def max_tokens(self) -> Optional[int]:
        """Context bound imposed by the block-table width (None = unbounded)."""
        if self.max_blocks_per_seq is None:
            return None
        return self.max_blocks_per_seq * self.block_size


@dataclass(frozen=True)
class RingKVProvider(_PagedPoolProvider):
    """Sliding-window paged KV: a fixed ring of ceil(window/bs)+1 blocks per
    sequence; token at position p lives in table[(p // bs) % ring] at offset
    p % bs, so long generations stop consuming new blocks."""
    window: int = 0
    draft: int = 0   # speculative slack: K-1 extra in-flight positions

    kind = "ring"
    supports_prefix_caching = False  # ring content depends on wrap position

    @property
    def ring_pages(self) -> int:
        return ring_pages(self.window, self.block_size, draft=self.draft)

    def blocks_needed(self, total_tokens: int) -> int:
        return min(_ceil_div(total_tokens, self.block_size), self.ring_pages)

    def max_tokens(self) -> Optional[int]:
        return None  # the ring wraps: any length fits in ring_pages blocks

    def write_cursor(self, seq_len: int) -> dict:
        """Where token `seq_len` will be written: a pure function of the
        token count, which is WHY ring preemption needs no snapshot — the
        re-prefill of the same tokens reproduces the ring wrap-for-wrap."""
        return {"page": (seq_len // self.block_size) % self.ring_pages,
                "offset": seq_len % self.block_size}


@dataclass(frozen=True)
class RecurrentSlabProvider:
    """O(1) recurrent state: one slab row per engine slot, no block
    accounting. Rows are zeroed when a new request takes the slot and
    updates are masked for inactive slots, so a mid-prefill neighbour is
    never corrupted by the batched decode step."""
    cfg: ModelConfig
    max_slots: int
    kind: str                         # "rwkv" | "mamba"

    supports_prefix_caching = False
    supports_snapshot_resume = True   # O(1) state: checkpoint beats re-scan

    def _spec(self):
        if self.kind == "rwkv":
            return S.rwkv6_state_spec(self.cfg)
        if self.kind == "mamba":
            return S.mamba2_state_spec(self.cfg)
        raise ValueError(self.kind)

    def init_layer_state(self):
        return {k: jnp.zeros((self.max_slots,) + shape, dt)
                for k, (shape, dt) in self._spec().items()}

    def blocks_needed(self, total_tokens: int) -> int:
        return 0

    def max_tokens(self) -> Optional[int]:
        return None

    def state_bytes_per_slot(self, total_tokens: int) -> int:
        return sum(int(np.prod(shape)) * np.dtype(dt).itemsize
                   for shape, dt in self._spec().values())

    def defrag_remap(self, state, perm):
        return state  # slot-indexed, block moves don't touch it

    def preempt_checkpoint(self, state, slot: int):
        """Host snapshot of one slot's recurrent state. Leaves are
        (n_sb, max_slots, ...) — slot axis 1."""
        return jax.tree.map(lambda a: np.asarray(a[:, slot]), state)

    def resume_restore(self, state, slot: int, snap):
        """Scatter a ``preempt_checkpoint`` snapshot back into `slot` (the
        engine zeroes the slot first via reset, so restore is a plain set)."""
        return jax.tree.map(
            lambda a, s: a.at[:, slot].set(jnp.asarray(s)), state, snap)


# -------------------------------------------------- speculative rollback
def select_checkpoint(checkpoints, accepts, old):
    """Roll rejected draft tokens back to the accepted recurrent state.

    ``checkpoints`` leaves: (n_sb, K, max_slots, ...) — the per-draft-step
    states captured by the verify scan (checkpoint j = state after
    processing drafts 0..j). ``accepts``: (max_slots,) int32 tokens accepted
    this step (1..K; 0 marks an inactive slot). ``old``: the pre-verify slab
    (n_sb, max_slots, ...). Returns the slab advanced by exactly
    ``accepts`` tokens per slot: checkpoint ``accepts - 1`` where active,
    the untouched old state elsewhere. This is the ONLY sanctioned mutation
    of checkpointed recurrent state — keep callers out of the internals."""
    def sel(cps, o):
        K, S = cps.shape[1], cps.shape[2]
        cp = jnp.clip(accepts - 1, 0, K - 1)                      # (S,)
        w = (jnp.arange(K)[None, :, None] == cp[None, None, :])   # (1, K, S)
        w = w.reshape((1, K, S) + (1,) * (cps.ndim - 3))
        picked = jnp.sum(jnp.where(w, cps, jnp.zeros((), cps.dtype)), axis=1)
        act = (accepts > 0).reshape((1, S) + (1,) * (o.ndim - 2))
        return jnp.where(act, picked.astype(o.dtype), o)

    return jax.tree.map(sel, checkpoints, old)


# ----------------------------------------------------------------- assembly
def provider_for(skind: str, cfg: ModelConfig, *, num_blocks: int,
                 block_size: int, max_slots: int,
                 max_blocks_per_seq: Optional[int] = None, draft: int = 0,
                 kv_quant: Optional[KVQuantConfig] = None):
    if skind == "full":
        return PagedKVProvider(cfg, num_blocks, block_size, max_blocks_per_seq,
                               kv_quant)
    if skind == "ring":
        return RingKVProvider(cfg, num_blocks, block_size, max_blocks_per_seq,
                              kv_quant, window=cfg.window_size, draft=draft)
    if skind in ("rwkv", "mamba"):
        return RecurrentSlabProvider(cfg, max_slots, skind)
    raise ValueError(f"unknown state kind {skind!r}")


def providers_for(cfg: ModelConfig, *, num_blocks: int, block_size: int,
                  max_slots: int, max_blocks_per_seq: Optional[int] = None,
                  draft: int = 0, kv_quant: Optional[KVQuantConfig] = None):
    """One provider per layer of a superblock, aligned with layer_kinds(cfg).
    Layers of the same kind share a (frozen, equal) provider instance.
    ``draft`` = K - 1 when speculative decoding is on (ring slack);
    ``kv_quant`` switches the paged pools to int8 + per-vector scales."""
    cache = {}
    out = []
    for sk in state_kinds(cfg):
        if sk not in cache:
            cache[sk] = provider_for(
                sk, cfg, num_blocks=num_blocks, block_size=block_size,
                max_slots=max_slots, max_blocks_per_seq=max_blocks_per_seq,
                draft=draft, kv_quant=kv_quant)
        out.append(cache[sk])
    return out


def seq_blocks_needed(providers, total_tokens: int) -> int:
    """Blocks to reserve for one sequence of `total_tokens`. The block table
    is shared across layers, so the reservation is the max over kinds — a
    full-attention layer dominates a ring layer; recurrent layers are free."""
    return max((p.blocks_needed(total_tokens) for p in providers), default=0)


def state_memory_per_slot(cfg: ModelConfig, providers, total_tokens: int) -> int:
    """Whole-model sequence-state bytes for one busy slot at `total_tokens`
    context (all superblocks)."""
    n_sb, _ = superblock_layout(cfg)
    return n_sb * sum(p.state_bytes_per_slot(total_tokens) for p in providers)
