"""Generic decoder assembled from a ModelConfig.

Layers are grouped into *superblocks* and scanned (`jax.lax.scan`) so the
lowered HLO is O(1) in depth — essential for compiling 48-layer models with
512 placeholder devices on one CPU core:

  family                superblock
  ------                ----------
  dense/vlm/audio/moe   1 layer (attn + mlp|moe)
  local_global (gemma3) ratio local layers + 1 global layer
  ssm (rwkv6)           time-mix + channel-mix
  hybrid (zamba2)       N mamba2 layers + 1 *shared-weight* attention layer

Entry points:
  init_params(cfg, key)
  forward(cfg, params, inputs)                  -> hidden (B,S,D), aux
  loss_fn(cfg, params, batch)                   -> scalar loss (chunked CE)
  init_decode_state(cfg, batch, max_len)        -> stacked per-superblock caches
  decode_step(cfg, params, state, inputs, idx)  -> logits (B,1,V), new state
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.parallelism import constrain
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import state_providers as SP

# superblock layout / kind lists live in state_providers so the engine's
# host-side accounting derives the SAME static structure (no import cycle)
superblock_layout = SP.superblock_layout
_layer_kinds = SP.layer_kinds


# ------------------------------------------------------------------ param init
def _init_attn_layer(key, cfg, with_moe=False):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "attn": A.init_attention(k1, cfg),
        "ln2": L.init_rmsnorm(cfg.d_model),
    }
    if with_moe:
        p["moe"] = M.init_moe(k2, cfg)
    else:
        p["mlp"] = L.init_swiglu(k3, cfg.d_model, cfg.d_ff, L.dtype_of(cfg))
    return p


def _init_superblock(key, cfg):
    kinds = _layer_kinds(cfg)
    keys = jax.random.split(key, len(kinds))
    out = {}
    for i, (kind, k) in enumerate(zip(kinds, keys)):
        if kind in ("attn", "local", "global"):
            out[f"l{i}"] = _init_attn_layer(k, cfg, with_moe=False)
        elif kind == "moe_attn":
            out[f"l{i}"] = _init_attn_layer(k, cfg, with_moe=True)
        elif kind == "rwkv":
            out[f"l{i}"] = {
                "ln1": L.init_rmsnorm(cfg.d_model),
                "rwkv": S.init_rwkv6(k, cfg),
                "ln2": L.init_rmsnorm(cfg.d_model),
            }
        elif kind == "mamba":
            k1, k2 = jax.random.split(k)
            out[f"l{i}"] = {
                "ln1": L.init_rmsnorm(cfg.d_model),
                "mamba": S.init_mamba2(k1, cfg),
                "ln2": L.init_rmsnorm(cfg.d_model),
                "mlp": L.init_swiglu(k2, cfg.d_model, cfg.d_ff, L.dtype_of(cfg)),
            }
        elif kind == "shared_attn":
            out[f"l{i}"] = {}  # weights live in params["shared_attn"]
    return out


def init_params(cfg: ModelConfig, key):
    n_sb, _ = superblock_layout(cfg)
    k_embed, k_blocks, k_head, k_shared = jax.random.split(key, 4)
    dt = L.dtype_of(cfg)
    params = {
        "embed": L.init_embedding(k_embed, cfg.vocab_size, cfg.d_model, dt),
        "blocks": jax.vmap(lambda k: _init_superblock(k, cfg))(
            jax.random.split(k_blocks, n_sb)),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": L.dense_init(k_head, (cfg.d_model, cfg.vocab_size), dt)}
    if cfg.family == "hybrid":
        params["shared_attn"] = _init_attn_layer(k_shared, cfg, with_moe=False)
    return params


# -------------------------------------------------------------------- forward
def _apply_layer_train(kind, lp, x, positions, cfg, shared):
    aux = jnp.float32(0.0)
    if kind in ("attn", "local", "global", "moe_attn", "shared_attn"):
        p = shared if kind == "shared_attn" else lp
        window = None
        if kind == "local" or (cfg.attention_type == "sliding" and kind in ("attn", "moe_attn")):
            window = cfg.window_size
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        x = x + A.attention_train(p["attn"], h, positions, cfg, window=window)
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if kind == "moe_attn":
            from repro.core.parallelism import current_plan
            plan = current_plan()
            if M.ep_applicable(cfg, plan):
                x = x + M.moe_apply_ep(lp["moe"], h, cfg, plan)
            else:
                x = x + M.moe_apply(lp["moe"], h, cfg, constrain=constrain)
            aux = M.load_balance_loss(lp["moe"], h, cfg)
        else:
            x = x + L.swiglu(p["mlp"], h)
    elif kind == "rwkv":
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        y, _ = S.rwkv6_mix(lp["rwkv"], h, cfg)
        x = x + y
        h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        y, _ = S.rwkv6_channel_mix(lp["rwkv"], h, cfg)
        x = x + y
    elif kind == "mamba":
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        y, _ = S.mamba2_mix(lp["mamba"], h, cfg)
        x = x + y
        h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + L.swiglu(lp["mlp"], h)
    else:
        raise ValueError(kind)
    return x, aux


def forward(cfg: ModelConfig, params, inputs):
    """inputs: {"tokens": (B,S)} or {"embeds": (B,S,D)}, optional "positions".
    Returns (hidden (B,S,D), aux_loss)."""
    if "embeds" in inputs:
        x = inputs["embeds"].astype(L.dtype_of(cfg))
    else:
        x = L.embed(params["embed"], inputs["tokens"])
        if cfg.family != "ssm":
            x = x * float(np.sqrt(cfg.d_model))
    B, Sq = x.shape[0], x.shape[1]
    positions = inputs.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
        if cfg.rope_mode == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, B, Sq))
    x = constrain(x, ("batch", "seq", None))
    kinds = _layer_kinds(cfg)
    shared = params.get("shared_attn")

    layer_fn = _apply_layer_train
    if cfg.remat and len(kinds) > 1:
        # nested remat: the superblock checkpoint stores only its input; each
        # inner layer is checkpointed again so the superblock's backward pass
        # holds one layer's intermediates at a time, not all of them (§Perf)
        layer_fn = jax.checkpoint(_apply_layer_train, static_argnums=(0, 4))

    def sb_fn(x, sb_params):
        aux = jnp.float32(0.0)
        for i, kind in enumerate(kinds):
            x, a = layer_fn(kind, sb_params[f"l{i}"], x, positions, cfg, shared)
            aux = aux + a
        x = constrain(x, ("batch", "seq", None))
        return x, aux

    if cfg.remat:
        sb_fn = jax.checkpoint(sb_fn)

    def scan_body(x, sb_params):
        return sb_fn(x, sb_params)

    x, auxs = jax.lax.scan(scan_body, x, params["blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.sum(auxs)


def logits(cfg: ModelConfig, params, hidden):
    if cfg.tie_embeddings:
        return L.unembed(params["embed"], hidden)
    return jnp.einsum("...d,dv->...v", hidden, params["lm_head"]["w"])


def loss_fn(cfg: ModelConfig, params, batch):
    """Chunked cross-entropy: scans over sequence chunks so the (B,S,V) logits
    tensor is never materialized (vocabs up to 262k)."""
    hidden, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    B, Sq, D = hidden.shape
    chunk = min(cfg.loss_chunk, Sq)
    nc = Sq // chunk
    head = params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]["w"].T
    # head: (V, D)

    hc = hidden.reshape(B, nc, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def step(tot, inp):
        # rematerialized: the (B, chunk, V) logits block is recomputed in the
        # backward pass instead of being stored (vocab up to 262k).
        # gold logit via one-hot contraction, NOT take_along_axis: the gather
        # would force an all-gather of the vocab-sharded logits (§Perf).
        h, lab = inp
        lg = jnp.einsum("bcd,vd->bcv", h, head).astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        onehot = (lab[..., None] == jnp.arange(lg.shape[-1])).astype(jnp.float32)
        gold = jnp.sum(lg * onehot, axis=-1)
        return tot + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(step, jnp.float32(0.0), (hc, lc))
    ce = total / (B * Sq)
    return ce + 0.01 * aux


# --------------------------------------------------------------------- decode
def _layer_cache(kind, cfg, batch, max_len, kv_quant=None):
    if kind in ("attn", "moe_attn", "global", "shared_attn"):
        window = cfg.window_size if cfg.attention_type == "sliding" else None
        return A.init_kv_cache(cfg, batch, max_len, window=window,
                               kv_quant=kv_quant)
    if kind == "local":
        return A.init_kv_cache(cfg, batch, max_len, window=cfg.window_size,
                               kv_quant=kv_quant)
    if kind == "rwkv":
        return S.init_rwkv6_state(cfg, batch)
    if kind == "mamba":
        return S.init_mamba2_state(cfg, batch)
    raise ValueError(kind)


def init_decode_state(cfg: ModelConfig, batch, max_len, kv_quant=None):
    n_sb, _ = superblock_layout(cfg)
    kinds = _layer_kinds(cfg)
    one = {f"l{i}": _layer_cache(k, cfg, batch, max_len, kv_quant)
           for i, k in enumerate(kinds)}
    # stack per superblock
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n_sb,) + a.shape), one)


def _apply_layer_decode(kind, lp, cache, x, index, cfg, shared):
    if kind in ("attn", "local", "global", "moe_attn", "shared_attn"):
        p = shared if kind == "shared_attn" else lp
        window = None
        if kind == "local" or (cfg.attention_type == "sliding" and kind in ("attn", "moe_attn")):
            window = cfg.window_size
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, cache = A.attention_decode(p["attn"], h, cache, index, cfg, window=window)
        x = x + y
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if kind == "moe_attn":
            x = x + M.moe_apply(lp["moe"], h, cfg)
        else:
            x = x + L.swiglu(p["mlp"], h)
    elif kind == "rwkv":
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        y, new = S.rwkv6_mix(lp["rwkv"], h, cfg,
                             state={"S": cache["S"], "prev": cache["prev"]})
        x = x + y
        h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        y, prev_cm = S.rwkv6_channel_mix(lp["rwkv"], h, cfg, state=cache["prev_cm"])
        x = x + y
        cache = {"S": new["S"], "prev": new["prev"], "prev_cm": prev_cm}
    elif kind == "mamba":
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        y, cache = S.mamba2_mix(lp["mamba"], h, cfg, state=cache)
        x = x + y
        h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + L.swiglu(lp["mlp"], h)
    else:
        raise ValueError(kind)
    return x, cache


_ATTN_KINDS = ("attn", "local", "global", "moe_attn", "shared_attn")


def supports_batched_prefill(cfg: ModelConfig) -> bool:
    """True when every layer kind has a one-shot prefill path (attention
    families; recurrent ssm/hybrid states still prefill token-by-token)."""
    return all(k in _ATTN_KINDS for k in _layer_kinds(cfg))


def _embed_tokens(cfg, params, tokens):
    x = L.embed(params["embed"], tokens)
    if cfg.family != "ssm":
        x = x * float(np.sqrt(cfg.d_model))
    return x


def prefill_step(cfg: ModelConfig, params, state, inputs):
    """Batched prefill: run the WHOLE prompt through every layer in one jitted
    call, filling the decode cache (vs. the O(S) sequential reference loop).
    inputs: {"tokens": (B, S0)}. Returns (logits (B,V) of the last prompt
    token, new state)."""
    if not supports_batched_prefill(cfg):
        raise NotImplementedError(
            f"batched prefill needs attention-only layers, got {_layer_kinds(cfg)}")
    x = _embed_tokens(cfg, params, inputs["tokens"])
    kinds = _layer_kinds(cfg)
    shared = params.get("shared_attn")

    def scan_body(x, sb):
        sb_params, sb_cache = sb
        new_cache = {}
        for i, kind in enumerate(kinds):
            p = shared if kind == "shared_attn" else sb_params[f"l{i}"]
            lp = sb_params[f"l{i}"]
            window = None
            if kind == "local" or (cfg.attention_type == "sliding"
                                   and kind in ("attn", "moe_attn")):
                window = cfg.window_size
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            y, c = A.attention_prefill(p["attn"], h, sb_cache[f"l{i}"], cfg,
                                       window=window)
            x = x + y
            h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
            if kind == "moe_attn":
                x = x + M.moe_apply(lp["moe"], h, cfg)
            else:
                x = x + L.swiglu(p["mlp"], h)
            new_cache[f"l{i}"] = c
        return x, new_cache

    x, new_caches = jax.lax.scan(scan_body, x, (params["blocks"], state))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    lg = logits(cfg, params, x[:, -1:])[:, 0]
    return lg, new_caches


# -------------------------------------------------------------- paged decode
def init_paged_state(cfg: ModelConfig, num_blocks: int, block_size: int,
                     max_slots: int = None, kv_quant=None):
    """Per-superblock, per-layer sequence state, built by the layer's state
    provider (see models.state_providers):

      full / ring layers — paged KV pools (n_sb, num_blocks, bs, Hkv, hd);
        all layers share ONE block table per sequence, each layer owns its
        pool storage. Ring layers reuse the table's first ring_pages entries
        modulo the ring.
      rwkv / mamba layers — per-slot recurrent slabs (n_sb, max_slots, ...);
        no block accounting at all.

    `max_slots` is required whenever the config has recurrent layers.
    `kv_quant` (KVQuantConfig) switches the paged pools to int8 values with
    per-vector f32 scales; the dict structure carries the mode so the jitted
    steps dispatch statically."""
    kinds = _layer_kinds(cfg)
    skinds = SP.state_kinds(cfg)
    if any(k in ("rwkv", "mamba") for k in skinds) and max_slots is None:
        raise ValueError("recurrent layers need max_slots for their state slab")
    n_sb, _ = superblock_layout(cfg)
    providers = SP.providers_for(cfg, num_blocks=num_blocks,
                                 block_size=block_size,
                                 max_slots=max_slots or 0,
                                 kv_quant=kv_quant)
    one = {f"l{i}": p.init_layer_state() for i, p in enumerate(providers)}
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n_sb,) + a.shape), one)


def _attn_block(kind, p, lp, h_in, cfg, attn_out):
    """Residual + MLP/MoE tail shared by every attention-layer dispatch."""
    x = h_in + attn_out
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind == "moe_attn":
        return x + M.moe_apply(lp["moe"], h, cfg)
    return x + L.swiglu(p["mlp"], h)


def paged_decode_step(cfg: ModelConfig, params, pool, inputs, block_tables,
                      positions, attn_lens, *, impl="ref", interpret=None,
                      draft=0):
    """One-token decode for a continuous batch of slots, dispatching each
    layer to its state kind. inputs: {"token": (B,)}; block_tables: (B, P);
    positions: (B,) absolute position of each incoming token; attn_lens:
    (B,) tokens to attend over including the new one (0 = inactive slot).
    Recurrent slabs are per-slot (B == max_slots) and their updates are
    masked for inactive slots, so slots mid-prefill are never corrupted by
    the batched decode. ``draft`` must match the engine's speculative K-1
    (0 when speculation is off) so ring layers use the same enlarged ring
    as the verify step. Returns (logits (B,V), new pool)."""
    x = _embed_tokens(cfg, params, inputs["token"][:, None])
    kinds = _layer_kinds(cfg)
    skinds = SP.state_kinds(cfg)
    shared = params.get("shared_attn")
    active = attn_lens > 0

    def scan_body(x, sb):
        sb_params, sb_pool = sb
        new_pool = {}
        for i, (kind, skind) in enumerate(zip(kinds, skinds)):
            lp = sb_params[f"l{i}"]
            st = sb_pool[f"l{i}"]
            if skind in ("full", "ring"):
                p = shared if kind == "shared_attn" else lp
                window = cfg.window_size if skind == "ring" else None
                rp = (SP.ring_pages(window, st["k"].shape[1], draft=draft)
                      if skind == "ring" else None)
                h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
                y, kv = A.attention_decode_paged(
                    p["attn"], h, st, block_tables, positions, attn_lens,
                    cfg, impl=impl, interpret=interpret, window=window,
                    ring_pages=rp)
                x = _attn_block(kind, p, lp, x, cfg, y)
                new_pool[f"l{i}"] = kv
            else:
                x1, new_st = _apply_layer_decode(kind, lp, st, x,
                                                 jnp.int32(0), cfg, shared)
                new_st = jax.tree.map(
                    lambda n, o: jnp.where(
                        active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
                    new_st, st)
                x = x1
                new_pool[f"l{i}"] = new_st
        return x, new_pool

    x, new_pools = jax.lax.scan(scan_body, x, (params["blocks"], pool))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    lg = logits(cfg, params, x)[:, 0]
    return lg, new_pools


def _recurrent_verify_layer(kind, lp, slab, x, cfg, shared):
    """Speculative verify through a recurrent layer: a K-step token scan of
    the decode path that CAPTURES every intermediate state. slab leaves:
    (max_slots, ...); x: (B, K, D) with B == max_slots. Returns
    (y (B, K, D), checkpoints) where checkpoint leaves are (K, max_slots,
    ...) — checkpoint j is the state after processing draft tokens 0..j, so
    the caller can roll rejected drafts back exactly by selecting
    checkpoint `k_accepted - 1` (state_providers.select_checkpoint)."""
    def body(st, t):
        xt = jax.lax.dynamic_slice_in_dim(x, t, 1, axis=1)        # (B,1,D)
        yt, new = _apply_layer_decode(kind, lp, st, xt, t, cfg, shared)
        return new, (yt[:, 0], new)

    _, (ys, cps) = jax.lax.scan(body, slab, jnp.arange(x.shape[1]))
    return ys.swapaxes(0, 1), cps


def paged_verify_step(cfg: ModelConfig, params, pool, tokens, block_tables,
                      base, qlims, *, impl="ref", interpret=None):
    """Multi-query speculative verify for a continuous batch of slots.
    tokens: (B, K) — K draft tokens per slot, draft j at absolute position
    `base[b] + j`; qlims: (B,) number of draft positions that may commit
    K/V this step (0 = inactive slot). Paged layers write the first
    qlims[b] drafts' K/V (write-then-attend) and attend causally among the
    draft positions; recurrent layers scan the K tokens capturing per-step
    checkpoint states for exact rollback. Returns (logits (B, K, V),
    new pool) where recurrent entries hold stacked checkpoints
    (n_sb, K, max_slots, ...) — the caller selects the accepted checkpoint
    via state_providers.select_checkpoint."""
    x = _embed_tokens(cfg, params, tokens)                        # (B, K, D)
    K = tokens.shape[1]
    kinds = _layer_kinds(cfg)
    skinds = SP.state_kinds(cfg)
    shared = params.get("shared_attn")

    def scan_body(x, sb):
        sb_params, sb_pool = sb
        new_pool = {}
        for i, (kind, skind) in enumerate(zip(kinds, skinds)):
            lp = sb_params[f"l{i}"]
            st = sb_pool[f"l{i}"]
            if skind in ("full", "ring"):
                p = shared if kind == "shared_attn" else lp
                window = cfg.window_size if skind == "ring" else None
                rp = (SP.ring_pages(window, st["k"].shape[1], draft=K - 1)
                      if skind == "ring" else None)
                h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
                y, kv = A.attention_verify_paged(
                    p["attn"], h, st, block_tables, base, qlims, cfg,
                    impl=impl, interpret=interpret, window=window,
                    ring_pages=rp)
                x = _attn_block(kind, p, lp, x, cfg, y)
                new_pool[f"l{i}"] = kv
            else:
                y, cps = _recurrent_verify_layer(kind, lp, st, x, cfg, shared)
                x = y
                new_pool[f"l{i}"] = cps
        return x, new_pool

    x, new_pools = jax.lax.scan(scan_body, x, (params["blocks"], pool))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    lg = logits(cfg, params, x)                                   # (B, K, V)
    return lg, new_pools


def _recurrent_prefill_layer(kind, lp, slab, x, valids, slots, cfg, shared):
    """Packed chunked prefill through a recurrent layer: a token scan of the
    decode path (recurrent state has no one-shot prefill), with per-segment
    state updates masked past `valids[g]` so each slab row ends at exactly
    its last real token. slab leaves: (max_slots, ...); x: (G, C, D);
    slots: (G,) slab row per segment — `slots[g] >= max_slots` marks a
    padded segment (its gather clamps to an arbitrary row and its write-back
    is dropped). Returns (y (G,C,D), new slab)."""
    max_slots = jax.tree.leaves(slab)[0].shape[0]
    st0 = jax.tree.map(lambda a: a[jnp.minimum(slots, max_slots - 1)], slab)

    def body(st, t):
        xt = jax.lax.dynamic_slice_in_dim(x, t, 1, axis=1)        # (G,1,D)
        yt, new = _apply_layer_decode(kind, lp, st, xt, t, cfg, shared)
        keep = t < valids                                         # (G,)
        st = jax.tree.map(
            lambda n, o: jnp.where(
                keep.reshape((-1,) + (1,) * (n.ndim - 1)), n, o), new, st)
        return st, yt[:, 0]

    stf, ys = jax.lax.scan(body, st0, jnp.arange(x.shape[1]))
    y = ys.swapaxes(0, 1)                                         # (G, C, D)
    slab = jax.tree.map(lambda a, s: a.at[slots].set(s, mode="drop"),
                        slab, stf)
    return y, slab


def paged_prefill_packed(cfg: ModelConfig, params, pool, tokens, tables,
                         starts, valids, slots, *, draft=0):
    """Segment-masked packed prefill: one prompt chunk per segment, all
    segments in ONE device call. tokens: (G, C) int32 — segment g's chunk
    starts at absolute position `starts[g]` with the first `valids[g]`
    tokens real; tables: (S, P) block-table rows indexed by `slots` (the
    engine passes its full device table so the rows are gathered in-jit).
    `slots[g] >= S` marks an all-padding segment: its table gather clamps,
    its paged writes drop (valids[g] == 0) and its recurrent-slab write-back
    drops, so padded segments never touch sequence state. Segments' block
    tables are disjoint where written, so packing G chunks is bit-identical
    to G separate calls. Returns (logits (G, V) of each segment's last
    valid token, new pool)."""
    x = _embed_tokens(cfg, params, tokens)
    kinds = _layer_kinds(cfg)
    skinds = SP.state_kinds(cfg)
    shared = params.get("shared_attn")
    rows = jnp.take(tables, jnp.minimum(slots, tables.shape[0] - 1), axis=0)

    def scan_body(x, sb):
        sb_params, sb_pool = sb
        new_pool = {}
        for i, (kind, skind) in enumerate(zip(kinds, skinds)):
            lp = sb_params[f"l{i}"]
            st = sb_pool[f"l{i}"]
            if skind in ("full", "ring"):
                p = shared if kind == "shared_attn" else lp
                h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
                if skind == "ring":
                    rp = SP.ring_pages(cfg.window_size, st["k"].shape[1],
                                       draft=draft)
                    y, kv = A.attention_prefill_ring(
                        p["attn"], h, st, rows, starts, valids, cfg,
                        window=cfg.window_size, ring_pages=rp)
                else:
                    y, kv = A.attention_prefill_paged(
                        p["attn"], h, st, rows, starts, valids, cfg)
                x = _attn_block(kind, p, lp, x, cfg, y)
                new_pool[f"l{i}"] = kv
            else:
                x, new_st = _recurrent_prefill_layer(
                    kind, lp, st, x, valids, slots, cfg, shared)
                new_pool[f"l{i}"] = new_st
        return x, new_pool

    x, new_pools = jax.lax.scan(scan_body, x, (params["blocks"], pool))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    idx = jnp.maximum(valids - 1, 0)                              # (G,)
    last = jnp.take_along_axis(x, idx[:, None, None], axis=1)     # (G, 1, D)
    lg = logits(cfg, params, last)[:, 0]
    return lg, new_pools


def paged_prefill_step(cfg: ModelConfig, params, pool, tokens, table_row,
                       start, valid_len, slot, *, draft=0):
    """Chunked prefill of ONE sequence into its per-kind state (a G=1
    packed call). tokens: (1, C) chunk starting at absolute position
    `start`, first `valid_len` real. `slot` locates the sequence's
    recurrent slab rows; paged layers use `table_row` (P,). Returns
    (logits (1,V) of the chunk's last valid token, new pool)."""
    return paged_prefill_packed(
        cfg, params, pool, tokens, table_row[None],
        jnp.asarray(start, jnp.int32)[None],
        jnp.asarray(valid_len, jnp.int32)[None],
        jnp.asarray(slot, jnp.int32)[None], draft=draft)


def decode_step(cfg: ModelConfig, params, state, inputs, index):
    """One-token decode. inputs: {"token": (B,)} or {"embed": (B,D)}.
    index: scalar int32 absolute position. Returns (logits (B,V), new_state)."""
    if "embed" in inputs:
        x = inputs["embed"][:, None, :].astype(L.dtype_of(cfg))
    else:
        x = L.embed(params["embed"], inputs["token"][:, None])
        if cfg.family != "ssm":
            x = x * float(np.sqrt(cfg.d_model))
    kinds = _layer_kinds(cfg)
    shared = params.get("shared_attn")

    def scan_body(x, sb):
        sb_params, sb_cache = sb
        new_cache = {}
        for i, kind in enumerate(kinds):
            x, c = _apply_layer_decode(kind, sb_params[f"l{i}"], sb_cache[f"l{i}"],
                                       x, index, cfg, shared)
            new_cache[f"l{i}"] = c
        return x, new_cache

    x, new_caches = jax.lax.scan(scan_body, x, (params["blocks"], state))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    lg = logits(cfg, params, x)[:, 0]
    return lg, new_caches
