"""Continuous-batching scheduler: request queue, block-budget admission,
chunked prefill interleaved with decode.

Policy (one engine `step()`):
  1. ADMIT  — pop waiting requests while a slot AND their full block
              reservation (prompt + max_new tokens, conservative: no
              preemption needed) are available.
  2. PREFILL — run up to `prefills_per_step` prompt chunks of admitted
              requests (chunk = `prefill_chunk` tokens), so long prompts
              never block the decode batch for more than one chunk.
  3. DECODE — one batched token step over every DECODING slot.

Requests are pure host-side state; all device work goes through the Engine's
jitted functions.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.serving.engine.paged_cache import BlockPool, BlockPoolError

WAITING, PREFILLING, DECODING, FINISHED = "waiting", "prefilling", "decoding", "finished"


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S0,) int32
    max_new: int
    temperature: float = 0.0
    key: Optional[object] = None        # PRNG key when temperature > 0
    stop_token: Optional[int] = None
    state: str = WAITING
    slot: int = -1
    prefilled: int = 0                  # prompt tokens already in the pool
    out_tokens: list = field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        if len(self.out_tokens) >= self.max_new:
            return True
        return (self.stop_token is not None and self.out_tokens
                and self.out_tokens[-1] == self.stop_token)


class Scheduler:
    def __init__(self, pool: BlockPool, *, max_slots: int,
                 max_blocks_per_seq: int, prefill_chunk: int,
                 prefills_per_step: int = 1):
        self.pool = pool
        self.max_slots = max_slots
        self.max_blocks_per_seq = max_blocks_per_seq
        self.prefill_chunk = prefill_chunk
        self.prefills_per_step = prefills_per_step
        self.waiting: deque = deque()
        self.running: dict = {}         # rid -> Request (PREFILLING|DECODING)
        self._free_slots = list(range(max_slots - 1, -1, -1))

    # ------------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        need = self.pool.blocks_for(req.prompt_len + req.max_new)
        if need > self.max_blocks_per_seq:
            raise ValueError(
                f"request {req.rid}: needs {need} blocks > table width "
                f"{self.max_blocks_per_seq}; raise max_blocks_per_seq/block_size")
        if need > self.pool.num_blocks:
            raise ValueError(f"request {req.rid}: larger than the whole pool")
        self.waiting.append(req)

    def admit(self) -> list:
        """Admission by free-block budget: reserve blocks for the whole
        sequence (prompt + max_new) up front — with no preemption this
        guarantees an admitted request always runs to completion."""
        admitted = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            need = self.pool.blocks_for(req.prompt_len + req.max_new)
            if not self.pool.can_alloc(need):
                break                   # FCFS: don't starve the head
            self.waiting.popleft()
            self.pool.alloc(req.rid, need)
            req.slot = self._free_slots.pop()
            req.state = PREFILLING
            self.running[req.rid] = req
            admitted.append(req)
        return admitted

    def next_prefills(self) -> list:
        """(request, start, valid_len) chunks to prefill this step."""
        work = []
        for req in self.running.values():
            if len(work) >= self.prefills_per_step:
                break
            if req.state == PREFILLING:
                start = req.prefilled
                valid = min(self.prefill_chunk, req.prompt_len - start)
                work.append((req, start, valid))
        return work

    def decode_batch(self) -> list:
        return [r for r in self.running.values() if r.state == DECODING]

    def finish(self, req: Request) -> None:
        req.state = FINISHED
        self.pool.free_seq(req.rid)
        self._free_slots.append(req.slot)
        del self.running[req.rid]
        req.slot = -1

    # --------------------------------------------------------------- status
    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def occupancy(self) -> float:
        """Fraction of decode slots doing useful work right now."""
        return len(self.running) / self.max_slots
