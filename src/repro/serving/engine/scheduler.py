"""Continuous-batching scheduler: request queue, block-budget admission with
prefix-cache matching, chunked prefill interleaved with decode.

Block budgets are provider-aware (`block_cost`, injected by the Engine from
models.state_providers): sliding-window sequences reserve at most the ring
length, recurrent (ssm) sequences reserve zero blocks and are admitted on
slot availability alone, and hybrid configs charge the max over their layer
kinds since every layer shares one block table.

Policy (one engine `step()`):
  1. ADMIT  — pop waiting requests while a slot AND their block reservation
              are available. With prefix caching, the incoming prompt's
              longest cached block-aligned prefix is aliased read-only into
              the new table (refcount +1 per block) and the reservation is
              charged ONLY for the uncached tail + generation budget, so a
              cache hit both skips prefill compute and admits earlier.
  2. PREFILL — pack up to `prefills_per_step` prompt chunks of admitted
              requests (chunk = `prefill_chunk` tokens, starting at the
              first uncached token) into ONE segment-masked device call,
              padded to a declared (chunk-length x num-segments) bucket so
              steady-state serving only ever hits AOT-warmed executables.
              Long prompts never block the decode batch for more than one
              chunk.
  3. DECODE — one batched token step over every DECODING slot.

Copy-on-write rule: if the cached prefix covers the WHOLE prompt, the last
matched block is not aliased — the engine copies its device content into a
private block and re-prefills only the final prompt token into that copy, so
the first-token logits exist and no shared block is ever written. Decode
appends always land in privately-owned blocks (the tail reservation), so
shared blocks stay read-only by construction.

Oversubscription (``engine.oversub``, enabled by passing an OversubConfig +
SLOPolicy): admission reserves only ``block_cost(prefill_len + 1)`` — the
prompt KV plus the first decode write — gated by the policy's watermark,
and the queue is ordered by (priority, rid) instead of pure FCFS. Decode
blocks are appended per step by the ENGINE (which owns the device tables);
when the pool can't satisfy an append the engine preempts a victim through
``preempt()``: fully written blocks of ``prompt + generated`` are published
to the prefix index first, every block is released, and the request rolls
back to WAITING with ``prefill_tokens = prompt + generated`` so ordinary
(cached-prefix) re-prefill resumes it bit-identically — under greedy
decoding the continuation argmaxes over identical KV, so outputs match the
never-preempted run exactly.

Requests are pure host-side state; all device work goes through the Engine's
jitted functions.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.serving.engine.paged_cache import (BlockPool, BlockPoolError,
                                              prefix_hashes)

WAITING, PREFILLING, DECODING, FINISHED = "waiting", "prefilling", "decoding", "finished"


def chunk_buckets_for(prefill_chunk: int, declared=()) -> tuple:
    """Normalize declared chunk-length buckets: sorted unique values, each in
    (0, prefill_chunk], with prefill_chunk itself always present so every
    chunk has a bucket. An empty declaration means one bucket of the full
    chunk length (exactly the pre-bucket behavior)."""
    buckets = sorted(set(int(b) for b in declared))
    for b in buckets:
        if not 0 < b <= prefill_chunk:
            raise ValueError(
                f"prefill bucket {b} outside (0, prefill_chunk="
                f"{prefill_chunk}]")
    if prefill_chunk not in buckets:
        buckets.append(prefill_chunk)
    return tuple(buckets)


def segment_buckets_for(prefills_per_step: int, packed: bool = True) -> tuple:
    """Segment-count buckets: powers of two below prefills_per_step plus
    prefills_per_step itself, so the largest packed call has an exact bucket
    and partial batches pad at most 2x. Unpacked engines only dispatch
    G=1 calls."""
    if not packed:
        return (1,)
    out, g = [], 1
    while g < prefills_per_step:
        out.append(g)
        g *= 2
    out.append(prefills_per_step)
    return tuple(out)


@dataclass
class PrefillBatch:
    """One packed prefill dispatch: up to `num_segments` prompt chunks (one
    per request) padded to the declared (chunk_len x num_segments) bucket.
    The engine pads missing segments with valid=0 and an out-of-range slot
    sentinel so they never touch sequence state."""
    segments: list                      # [(request, start, valid)]
    chunk_len: int                      # C bucket >= every segment's valid
    num_segments: int                   # G bucket >= len(segments)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S0,) int32
    max_new: int
    temperature: float = 0.0
    key: Optional[object] = None        # PRNG key when temperature > 0
    stop_token: Optional[int] = None
    state: str = WAITING
    slot: int = -1
    prefilled: int = 0                  # prefill tokens already in the pool
    out_tokens: list = field(default_factory=list)
    # prefix caching (filled in at submit/admit time)
    block_hashes: list = field(default_factory=list)   # chained, full blocks
    shared_blocks: int = 0              # cached blocks aliased at admission
    cow_src: Optional[int] = None       # block to copy-on-write, if any
    registered: int = 0                 # prefix blocks published to the index
    # oversubscription / preemption
    priority: int = 0                   # class, LOWER is more important
    arrive_t: Optional[float] = None    # submit timestamp (TTFT SLO gating)
    preempts: int = 0                   # times this request was evicted
    got_first: bool = False             # first_token already emitted (so a
                                        #   resumed prefill completion is an
                                        #   ordinary decode_token)
    prefill_tokens: Optional[np.ndarray] = None   # resume: prompt + generated
    snapshot: Optional[list] = None     # per-layer provider state snapshot
    snapshot_len: int = 0               # tokens the snapshot state covers

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def prefill_src(self) -> np.ndarray:
        """Tokens to prefill: the prompt, or prompt + already-generated
        tokens after a preemption (recompute-by-re-prefill)."""
        return self.prompt if self.prefill_tokens is None else self.prefill_tokens

    @property
    def prefill_len(self) -> int:
        return int(self.prefill_src.shape[0])

    @property
    def seq_tokens(self) -> int:
        """Total tokens whose state exists once the NEXT decode write lands:
        prompt plus everything generated (the growth/rollback unit)."""
        return self.prompt_len + len(self.out_tokens)

    @property
    def done(self) -> bool:
        if len(self.out_tokens) >= self.max_new:
            return True
        return (self.stop_token is not None and self.out_tokens
                and self.out_tokens[-1] == self.stop_token)


class Scheduler:
    def __init__(self, pool: BlockPool, *, max_slots: int,
                 max_blocks_per_seq: int, prefill_chunk: int,
                 prefills_per_step: int = 1, prefix_caching: bool = True,
                 block_cost=None, chunk_buckets=None, segment_buckets=None,
                 packed_prefill: bool = True, policy=None):
        self.pool = pool
        self.max_slots = max_slots
        self.max_blocks_per_seq = max_blocks_per_seq
        self.prefill_chunk = prefill_chunk
        self.prefills_per_step = prefills_per_step
        self.prefix_caching = prefix_caching
        self.packed_prefill = packed_prefill
        # oversubscription: an engine.oversub.SLOPolicy switches admission to
        # optimistic prompt-only reservation (watermark-gated) and the queue
        # to (priority, rid) order; None keeps the conservative
        # full-reservation FCFS scheduler.
        self.policy = policy
        self.chunk_buckets = (tuple(chunk_buckets) if chunk_buckets
                              else chunk_buckets_for(prefill_chunk))
        self.segment_buckets = (
            tuple(segment_buckets) if segment_buckets
            else segment_buckets_for(prefills_per_step, packed_prefill))
        # per-sequence block cost: total tokens -> blocks to reserve. The
        # engine injects the provider-aware cost (max over layer state
        # kinds: full = ceil(total/bs), ring = capped at the ring length,
        # recurrent = 0); the default is the uniform full-attention cost.
        self.block_cost = block_cost or pool.blocks_for
        self.waiting: deque = deque()
        self.running: dict = {}         # rid -> Request (PREFILLING|DECODING)
        self._free_slots = list(range(max_slots - 1, -1, -1))

    # ------------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        need = self.block_cost(req.prompt_len + req.max_new)
        if need > self.max_blocks_per_seq:
            raise ValueError(
                f"request {req.rid}: needs {need} blocks > table width "
                f"{self.max_blocks_per_seq}; raise max_blocks_per_seq/block_size")
        if need > self.pool.num_blocks:
            raise ValueError(f"request {req.rid}: larger than the whole pool")
        if self.prefix_caching:
            req.block_hashes = prefix_hashes(req.prefill_src,
                                             self.pool.block_size)
        self._enqueue(req)

    def _enqueue(self, req: Request) -> None:
        """Queue placement. Conservative mode is FCFS (append; rids are
        monotone). With a policy, order by (priority, rid): classes first,
        and within a class a preempted request's original rid makes resumed
        work senior to newer arrivals."""
        if self.policy is None:
            self.waiting.append(req)
            return
        key = (req.priority, req.rid)
        for i, other in enumerate(self.waiting):
            if (other.priority, other.rid) > key:
                self.waiting.insert(i, req)
                return
        self.waiting.append(req)

    def _admit_need(self, req: Request) -> int:
        """Blocks to reserve at admission. Conservative: the whole
        prompt + max_new span (an admitted request always completes).
        Optimistic (policy set): only the prefill tokens plus the first
        decode write — generation grows on demand, preemption reclaims."""
        if self.policy is None:
            return self.block_cost(req.prompt_len + req.max_new)
        return self.block_cost(req.prefill_len + 1)

    def _admit_plan(self, req: Request):
        """(matched, cow, need) for admitting `req` right now: the aliasable
        cached chain (minus a copy-on-write source when it covers the whole
        prefill), and the total block reservation."""
        matched = (self.pool.match_prefix(req.block_hashes)
                   if self.prefix_caching else [])
        cow = None
        if matched and len(matched) * self.pool.block_size == req.prefill_len:
            # whole prefill cached: don't alias the last block — the engine
            # copies it and re-runs the final token there to produce the
            # first-token logits (copy-on-write)
            cow = matched[-1]
            matched = matched[:-1]
        return matched, cow, self._admit_need(req)

    def _may_admit(self, matched: list, need: int) -> bool:
        if self.policy is None:
            return self.pool.admit_feasible(matched, need - len(matched))
        return self.policy.may_admit(
            self.pool, need - len(matched), self.pool.revive_count(matched),
            len(self.running))

    def can_admit_head(self) -> bool:
        """Would the queue head be admitted by the next `admit()` call?
        (The priority-preemption probe: False + a weaker victim running
        means eviction can unblock the head.)"""
        if not self.waiting:
            return True
        if not self._free_slots:
            return False
        matched, _, need = self._admit_plan(self.waiting[0])
        return self._may_admit(matched, need)

    def admit(self) -> list:
        """Admission by free-block budget. Conservative mode reserves blocks
        for the whole sequence (prompt + max_new) up front — with no
        preemption this guarantees an admitted request always runs to
        completion. Optimistic mode (policy set) reserves only the prefill
        span + 1 under the policy watermark. The reservation is the
        provider-aware `block_cost` (ring layers cap at the ring length,
        recurrent layers reserve nothing). Cached prefix blocks are aliased
        instead of allocated, so the budget only charges the uncached
        tail."""
        admitted = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            matched, cow, need = self._admit_plan(req)
            if not self._may_admit(matched, need):
                break                   # in-order: don't starve the head
            self.waiting.popleft()
            if self.prefix_caching:
                self.pool.note_prefix_lookup(
                    len(matched) + (1 if cow is not None else 0))
            if matched:
                self.pool.share(req.rid, matched)
            self.pool.alloc(req.rid, need - len(matched))
            req.shared_blocks = len(matched)
            req.cow_src = cow
            req.prefilled = (req.prefill_len - 1 if cow is not None
                             else len(matched) * self.pool.block_size)
            # shared blocks (and the CoW source's key) are already indexed
            req.registered = len(matched) + (1 if cow is not None else 0)
            req.slot = self._free_slots.pop()
            req.state = PREFILLING
            self.running[req.rid] = req
            admitted.append(req)
        return admitted

    def register_prefilled(self, req: Request) -> None:
        """Publish the request's fully-prefilled prefix blocks to the prefix
        index (chained hashes) so concurrent and future requests can alias
        them. First writer wins on each key."""
        if not self.prefix_caching:
            return
        row = self.pool.table(req.rid)
        full = min(req.prefilled, req.prefill_len) // self.pool.block_size
        while req.registered < min(full, len(req.block_hashes)):
            i = req.registered
            self.pool.register(req.rid, row[i], req.block_hashes[i])
            req.registered += 1

    def growth_need(self, req: Request, extra: int = 0) -> int:
        """Fresh blocks `req` must append before its next decode write
        lands (0 when the current table already covers it). Provider-aware:
        ring layers stop growing once the ring is full, recurrent layers
        never grow. ``extra`` widens the horizon past the one-token write —
        a speculative verify step commits up to qlims tokens at once, so
        the engine asks for qlims-1 extra."""
        return max(0, self.block_cost(req.seq_tokens + extra)
                   - len(self.pool.table(req.rid)))

    def grow(self, req: Request, extra: int = 0) -> list:
        """Append the blocks `growth_need` asks for (caller checked
        feasibility / preempted victims first). Returns the new block ids
        so the engine can extend the device table row."""
        need = self.growth_need(req, extra)
        return self.pool.append(req.rid, need) if need else []

    def preempt(self, req: Request) -> None:
        """Victim rollback: publish every fully written block of
        ``prompt + generated`` to the prefix index, release all blocks and
        the slot, and requeue the request as WAITING with
        ``prefill_tokens = prompt + generated`` so the ordinary
        (cached-prefix) admission path resumes it. The caller (engine) must
        have materialized ``out_tokens`` to concrete ints — and captured any
        provider snapshot — BEFORE calling; registration precedes the free
        so refcount-zero blocks park content-intact on the cold end of the
        free list and resume can alias them back."""
        if req.rid not in self.running:
            raise ValueError(f"preempt of non-running request {req.rid}")
        # tokens whose KV is actually written: everything prefilled while
        # PREFILLING; one behind prompt+generated while DECODING (the last
        # generated token is the pending input — its KV doesn't exist yet)
        covered = (req.seq_tokens - 1 if req.state == DECODING
                   else req.prefilled)
        if req.out_tokens:
            req.prefill_tokens = np.concatenate(
                [req.prompt, np.asarray(req.out_tokens, np.int32)])
        if self.prefix_caching:
            req.block_hashes = prefix_hashes(req.prefill_src,
                                             self.pool.block_size)
            row = self.pool.table(req.rid)
            full = min(covered // self.pool.block_size,
                       len(req.block_hashes), len(row))
            for i in range(req.registered, full):
                # first writer wins; a block matched at admission is already
                # indexed under the SAME chained hash (register no-ops)
                self.pool.register(req.rid, row[i], req.block_hashes[i])
        self.pool.evict_seq(req.rid)
        self._free_slots.append(req.slot)
        del self.running[req.rid]
        req.state = WAITING
        req.slot = -1
        req.prefilled = 0
        req.shared_blocks = 0
        req.cow_src = None
        req.registered = 0
        req.preempts += 1
        self._enqueue(req)

    def _chunk_bucket(self, valid: int) -> int:
        """Smallest declared chunk bucket covering `valid` tokens (always
        exists: prefill_chunk is declared and valid <= prefill_chunk)."""
        for c in self.chunk_buckets:
            if c >= valid:
                return c
        raise AssertionError(f"no chunk bucket >= {valid}")

    def _segment_bucket(self, n: int) -> int:
        """Smallest declared segment bucket covering `n` chunks (always
        exists: prefills_per_step is declared and n <= prefills_per_step)."""
        for g in self.segment_buckets:
            if g >= n:
                return g
        raise AssertionError(f"no segment bucket >= {n}")

    def next_prefills(self) -> list:
        """PrefillBatch objects to dispatch this step. Collects up to
        `prefills_per_step` (request, start, valid) prompt chunks, then packs
        them all into ONE batch at the smallest declared
        (chunk-length x num-segments) bucket — chunk_len covers the largest
        valid in the batch, num_segments covers the chunk count. Unpacked
        mode returns one G=1 batch per chunk (still bucket-padded, so the
        same AOT-warmed executables serve both modes)."""
        work = []
        for req in self.running.values():
            if len(work) >= self.prefills_per_step:
                break
            if req.state == PREFILLING:
                start = req.prefilled
                valid = min(self.prefill_chunk, req.prefill_len - start)
                work.append((req, start, valid))
        if not work:
            return []
        if self.packed_prefill:
            return [PrefillBatch(
                work, self._chunk_bucket(max(v for _, _, v in work)),
                self._segment_bucket(len(work)))]
        return [PrefillBatch([w], self._chunk_bucket(w[2]), 1) for w in work]

    def decode_batch(self) -> list:
        return [r for r in self.running.values() if r.state == DECODING]

    def finish(self, req: Request) -> None:
        req.state = FINISHED
        self.pool.free_seq(req.rid)
        self._free_slots.append(req.slot)
        del self.running[req.rid]
        req.slot = -1

    # --------------------------------------------------------------- status
    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def occupancy(self) -> float:
        """Fraction of slots doing useful DECODE work right now. Slots still
        prefilling contribute nothing to the decode batch, so they are
        excluded — this matches the engine's `engine_occupancy_sum`, which
        accumulates decode_batch / max_slots per decode step."""
        return sum(1 for r in self.running.values()
                   if r.state == DECODING) / self.max_slots
