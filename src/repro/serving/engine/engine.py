"""Continuous-batching serving engine front-end.

Sequence state is pluggable per layer kind (models.state_providers): full
attention pages O(S) KV blocks, sliding-window layers keep a fixed ring of
``ceil(window/block_size)+1`` blocks written modulo the ring, and rwkv6 /
mamba2 layers keep O(1) per-slot state slabs — so the engine serves the
full, sliding, ssm, AND hybrid families through one scheduler and one
block-table layout. Admission charges the per-kind block cost (max over
kinds; recurrent layers are free) and prefix caching stays on exactly for
the all-full-attention configs where block aliasing is sound.

Wires the host-side scheduler + block-pool bookkeeping to two jitted device
functions over the per-kind sequence state:

  * ``paged_prefill_packed`` — up to ``prefills_per_step`` prompt chunks of
    DIFFERENT requests packed into one segment-masked call, padded to a
    declared (chunk-length x num-segments) bucket. Every bucket is compiled
    once at engine construction (``_warmup_prefill``), so steady-state
    serving never traces a new prefill variant.
  * ``paged_decode_step``  — one token for EVERY decoding slot at once; new
    requests join and finished requests leave the batch between steps without
    recompilation (shapes are fixed at max_slots).

All per-slot batch state (next token, sequence lengths, active mask, block
tables) is DEVICE-resident and greedy sampling happens inside the jitted
step, so the steady-state decode loop is a single dispatch per step with no
host round-trip — the python scheduler runs ahead of the device and steps
pipeline. Host↔device traffic happens only at request lifecycle events
(admit / prefill chunk / finish) and for requests that need host-side
decisions (temperature sampling, stop_token scanning). Generated tokens are
recorded as whole per-step vectors and materialized once at drain.

Prefix caching (``EngineConfig.prefix_caching``, on by default): fully
prefilled prompt blocks are published to the pool's prefix index under
chained token hashes; a new request's longest cached block-aligned prefix is
aliased read-only into its table at admission and only the uncached tail is
prefilled. Because a block's KV content is a deterministic function of the
token prefix it covers, aliased blocks are bitwise identical to what the
request would have recomputed — greedy outputs stay bit-identical to
``serve.generate`` with caching on or off. A fully-cached prompt triggers
one copy-on-write block duplication (``copy_block_fn``) so the final prompt
token can be re-run privately for its logits.

A ``ShardingPlan`` may be passed for multi-device serving: params are placed
by the plan's rules and all device steps run under the plan context so
activation constraints apply.

Telemetry (``EngineConfig.telemetry``, on by default; see
``serving.telemetry`` and the README's Telemetry section): every request's
lifecycle (arrive/admit/prefix_hit/prefill_chunk/first_token/decode_token/
finish) is traced with monotonic timestamps, all engine and pool counters
live in one metrics registry (``Engine.stats`` remains as a back-compat
read-only view), the jitted step fns are wrapped to count unique trace keys
(distinct compiled variants), and prefill/decode run under
``jax.profiler.TraceAnnotation`` spans. ``EngineConfig.step_timing``
additionally blocks on device results inside ``step()`` to split host
scheduling time from device time per step — only the timing path blocks, so
throughput runs keep the async host-ahead pipeline. Telemetry never changes
emitted tokens: greedy outputs are bit-identical with it on or off.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import parallelism as par
from repro.kernels.quantize import KVQuantConfig
from repro.models import state_providers as SP
from repro.models import transformer as T
from repro.serving import telemetry as TM
from repro.serving.engine import spec as SPEC
from repro.serving.engine.oversub import OversubConfig, SLOPolicy
from repro.serving.engine.paged_cache import BlockPool
from repro.serving.engine.spec import SpecConfig
from repro.serving.engine.scheduler import (DECODING, FINISHED, PREFILLING,
                                            Request, Scheduler,
                                            chunk_buckets_for,
                                            segment_buckets_for)


@dataclass(frozen=True)
class EngineConfig:
    block_size: int = 16
    num_blocks: int = 128
    max_blocks_per_seq: int = 16        # block-table width P
    max_slots: int = 8                  # max concurrent sequences
    prefill_chunk: int = 32             # prompt tokens per prefill call
    prefills_per_step: int = 1          # chunks interleaved per engine step
    prefix_caching: bool = True         # alias cached prompt-prefix blocks
    attn_impl: str = "ref"              # "ref" | "kernel" (Pallas paged-decode)
    interpret: Optional[bool] = None    # kernel interpret mode (None: off-TPU)
    telemetry: bool = True              # lifecycle tracing + metrics registry
    step_timing: bool = False           # block per device call to time steps
    prefill_buckets: tuple = ()         # chunk-length buckets; () = one
                                        #   bucket of prefill_chunk tokens
    packed_prefill: bool = True         # pack chunks into one prefill call
    oversub: Optional[OversubConfig] = None   # optimistic admission + victim
                                        #   preemption (engine.oversub);
                                        #   None = conservative reservation
    spec: Optional[SpecConfig] = None   # speculative decoding (engine.spec):
                                        #   k-token draft + multi-query verify
                                        #   replaces the one-token decode step
    kv_quant: Optional[KVQuantConfig] = None  # int8 paged KV + per-vector f32
                                        #   scales, dequantized inside the
                                        #   paged Pallas kernels

    def __post_init__(self):
        # keep the config hashable for the compiled-step cache even when a
        # caller declares the buckets as a list
        object.__setattr__(self, "prefill_buckets",
                           tuple(self.prefill_buckets))


def _build_step_fns(cfg, e: EngineConfig, plan):
    """The jitted device functions. Cached per (cfg, EngineConfig) for
    the plan-less path so repeated Engine construction re-uses the compiled
    steps (mirrors serve._cached_decode_step)."""
    skinds = SP.state_kinds(cfg)
    # speculative decoding enlarges every ring layer by the draft depth so a
    # verify step's K in-flight positions never overwrite a key still inside
    # someone's window — decode, prefill and verify must all index the ring
    # with the SAME enlarged modulus, hence the shared `draft` here.
    draft = e.spec.k - 1 if e.spec is not None else 0

    def in_plan(fn):
        @functools.wraps(fn)
        def wrapped(*a, **kw):
            if plan is None:
                return fn(*a, **kw)
            with par.plan_context(plan):
                return fn(*a, **kw)
        return wrapped

    @functools.partial(jax.jit, donate_argnums=(1,))
    @in_plan
    def decode_fn(params, pool, tokens, tables, seq_lens, active):
        positions = jnp.where(active, seq_lens, 0)
        attn_lens = jnp.where(active, seq_lens + 1, 0)
        logits, pool = T.paged_decode_step(
            cfg, params, pool, {"token": tokens}, tables, positions,
            attn_lens, impl=e.attn_impl, interpret=e.interpret, draft=draft)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return greedy, logits, seq_lens + active, pool

    @functools.partial(jax.jit, donate_argnums=(1,))
    @in_plan
    def prefill_fn(params, pool, tokens, tables, starts, valids, slots):
        # packed: tokens (G, C) — one bucket-padded chunk per segment;
        # starts/valids/slots (G,). Padded segments carry valid == 0 and
        # slot == max_slots (OOB sentinel), so their writes all drop.
        logits, pool = T.paged_prefill_packed(
            cfg, params, pool, tokens, tables, starts, valids, slots,
            draft=draft)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return greedy, logits, pool

    verify_fn = None
    if e.spec is not None:
        @functools.partial(jax.jit, donate_argnums=(1,))
        @in_plan
        def verify_fn(params, pool, tokens, tables, seq_lens, active, qlims):
            # one dispatch verifies K tokens per slot and computes the
            # greedy acceptance run in-jit (spec.verify_step)
            return SPEC.verify_step(
                cfg, params, pool, tokens, tables, seq_lens, active, qlims,
                impl=e.attn_impl, interpret=e.interpret)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def copy_block_fn(pool, src, dst):
        # copy-on-write: duplicate one KV block (all layers) so a request
        # whose prompt is fully cached can re-run its last token privately.
        # Only reached with prefix caching on, i.e. every leaf is a paged
        # pool indexed (n_sb, block, ...).
        return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), pool)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def reset_slot_fn(pool, slot):
        # zero one slot's recurrent slab rows across all layers: a new
        # occupant must not see the previous request's final state
        out = {}
        for i, sk in enumerate(skinds):
            st = pool[f"l{i}"]
            if sk in ("rwkv", "mamba"):
                st = jax.tree.map(lambda a: a.at[:, slot].set(0), st)
            out[f"l{i}"] = st
        return out

    return decode_fn, prefill_fn, copy_block_fn, reset_slot_fn, verify_fn


def _step_fn_key(e: EngineConfig) -> EngineConfig:
    """Host-only fields (scheduler policy, prefix caching, telemetry, bucket
    declarations) are never read by the traced functions — the traced shapes
    come from the call-time arrays — so normalize them out of the
    compile-cache key and toggling them reuses the compiled steps. Of the
    spec config only k matters (it sets the ring modulus and the verify
    tokens width); the drafter is pure host state. ``kv_quant`` stays in the
    key: it changes the pool pytree structure the steps are traced with."""
    spec = SpecConfig(k=e.spec.k) if e.spec is not None else None
    return dataclasses.replace(e, prefix_caching=True, prefills_per_step=1,
                               telemetry=True, step_timing=False,
                               prefill_buckets=(), packed_prefill=True,
                               oversub=None, spec=spec)


@functools.lru_cache(maxsize=None)
def _cached_step_fns(cfg, e: EngineConfig):
    return _build_step_fns(cfg, e, None)


class Engine:
    def __init__(self, cfg, params, engine_cfg: EngineConfig = None, plan=None):
        self.cfg = cfg
        self.ecfg = engine_cfg or EngineConfig()
        self.plan = plan
        if plan is not None:
            params = jax.device_put(params, plan.param_shardings(params))
        self.params = params
        e = self.ecfg

        # speculative decoding: the drafter is host-only per-engine state;
        # the device sees only k (verify tokens width + ring slack)
        self.spec = e.spec
        self.drafter = e.spec.build_drafter() if e.spec is not None else None

        # one state provider per superblock layer (models.state_providers):
        # paged full-attention KV, ring-paged sliding-window KV, or per-slot
        # recurrent slabs. The providers drive device-state init, per-kind
        # block costs for admission, and defrag remapping.
        self.providers = SP.providers_for(
            cfg, num_blocks=e.num_blocks, block_size=e.block_size,
            max_slots=e.max_slots, max_blocks_per_seq=e.max_blocks_per_seq,
            draft=e.spec.k - 1 if e.spec is not None else 0,
            kv_quant=e.kv_quant)
        self.state_kinds = [p.kind for p in self.providers]
        self._has_recurrent = any(k in ("rwkv", "mamba")
                                  for k in self.state_kinds)
        for p in self.providers:
            if p.kind == "ring" and p.ring_pages > e.max_blocks_per_seq:
                raise ValueError(
                    f"ring needs {p.ring_pages} blocks (window "
                    f"{p.window} @ block_size {e.block_size}) > "
                    f"max_blocks_per_seq {e.max_blocks_per_seq}")
        # block aliasing is only sound when every layer's state is a pure
        # function of the token prefix — i.e. all-full-attention configs
        self.prefix_caching = (e.prefix_caching and all(
            p.supports_prefix_caching for p in self.providers))

        # telemetry: one registry + tracer + recompile tracker per engine.
        # The pool shares the registry so `pool_*` metrics export alongside
        # `engine_*`; everything is host-side and disabled-path cheap.
        self.telemetry = TM.Telemetry(enabled=e.telemetry,
                                      step_timing=e.step_timing)
        reg = self.telemetry.registry
        self._m_decode_steps = reg.counter(
            "engine_decode_steps_total", "batched decode steps dispatched")
        self._m_prefill_chunks = reg.counter(
            "engine_prefill_chunks_total", "prompt prefill chunks dispatched")
        self._m_emitted = reg.counter(
            "engine_tokens_emitted_total", "tokens emitted across requests")
        self._m_occupancy = reg.counter(
            "engine_occupancy_sum",
            "sum over decode steps of decode_batch/max_slots")
        self._m_prefix_hits = reg.counter(
            "engine_prefix_hit_tokens_total",
            "prompt tokens served from the prefix cache")
        self._m_cow = reg.counter(
            "engine_cow_copies_total", "copy-on-write block duplications")
        self._m_defrags = reg.counter(
            "engine_defrags_total", "pool defragmentation passes")
        self._m_step_syncs = reg.counter(
            "engine_step_vector_syncs_total",
            "step vectors materialized on host for stop_token scanning")
        self._m_preempts = reg.counter(
            "engine_preemptions_total", "victims evicted and rolled back")
        self._m_resumes = reg.counter(
            "engine_resumes_total", "preempted requests re-admitted")
        self._m_appends = reg.counter(
            "engine_block_appends_total",
            "blocks appended on demand to decoding sequences")
        self._m_prefill_deferrals = reg.counter(
            "engine_prefill_deferrals_total",
            "steps that skipped prefill under SLO/pool pressure")
        self._m_verify_steps = reg.counter(
            "engine_verify_steps_total", "speculative verify steps dispatched")
        self._m_draft = reg.counter(
            "engine_draft_tokens_total", "draft tokens proposed for verify")
        self._m_accepted = reg.counter(
            "engine_accepted_tokens_total", "draft tokens accepted by verify")
        self._h_accept = reg.histogram(
            "engine_spec_acceptance_rate",
            "per verify step: accepted drafts / proposed drafts")
        self._g_waiting = reg.gauge(
            "engine_waiting_requests", "requests queued awaiting admission")
        self._g_running = reg.gauge(
            "engine_running_requests", "requests prefilling or decoding")
        self._g_free_blocks = reg.gauge(
            "pool_free_blocks", "allocatable blocks (incl. cached-free)")
        self._h_queue_wait = reg.histogram(
            "engine_request_queue_wait_seconds", "arrive -> admit wait")
        self._h_ttft = reg.histogram(
            "engine_request_ttft_seconds", "arrive -> first token")
        self._h_e2e = reg.histogram(
            "engine_request_e2e_seconds", "arrive -> finish")

        self.pool_state = T.init_paged_state(cfg, e.num_blocks, e.block_size,
                                             max_slots=e.max_slots,
                                             kv_quant=e.kv_quant)
        # HBM the int8 pools free up vs the fp32 layout (whole pool, all
        # layers and superblocks; 0 with quantization off)
        n_sb, _ = SP.superblock_layout(cfg)
        self._g_kv_quant_saved = reg.gauge(
            "kv_quant_bytes_saved_total",
            "pool bytes saved by KV quantization vs fp32 layout")
        self._g_kv_quant_saved.set(n_sb * sum(
            getattr(p, "pool_bytes_saved", lambda: 0)()
            for p in self.providers))
        on_evict = ((lambda b: self.telemetry.record(None, "evict", block=b))
                    if self.telemetry.enabled else None)
        self.block_pool = BlockPool(e.num_blocks, e.block_size,
                                    registry=reg, on_evict=on_evict)
        # declared AOT prefill buckets: every steady-state prefill dispatch
        # is padded to one of these (chunk length x segment count) shapes,
        # and ALL of them are compiled up front by _warmup_prefill
        self.chunk_buckets = chunk_buckets_for(e.prefill_chunk,
                                               e.prefill_buckets)
        self.segment_buckets = segment_buckets_for(e.prefills_per_step,
                                                   e.packed_prefill)
        self.prefill_grid = [(c, g) for c in self.chunk_buckets
                             for g in self.segment_buckets]
        self._m_bucket = {
            (c, g): reg.counter(
                f"engine_prefill_bucket_c{c}g{g}_dispatch_total",
                f"prefill dispatches at chunk bucket {c} x {g} segments")
            for c, g in self.prefill_grid}
        # oversubscription: the SLO policy flips the scheduler to optimistic
        # prompt-only reservation; the engine then appends decode blocks per
        # step and preempts victims when an append (or a higher-priority
        # queue head) can't be satisfied. Snapshot resume is sound only when
        # EVERY provider can restore from a snapshot (pure-recurrent
        # configs); hybrids recompute — the attention KV must be rebuilt
        # anyway and the slab prefill scan rebuilds recurrent state exactly.
        self._policy = SLOPolicy(e.oversub) if e.oversub is not None else None
        self._snapshot_resume = (
            e.oversub is not None and e.oversub.snapshot_resume
            and self._has_recurrent
            and all(getattr(p, "supports_snapshot_resume", False)
                    for p in self.providers))
        self.scheduler = Scheduler(
            self.block_pool, max_slots=e.max_slots,
            max_blocks_per_seq=e.max_blocks_per_seq,
            prefill_chunk=e.prefill_chunk,
            prefills_per_step=e.prefills_per_step,
            prefix_caching=self.prefix_caching,
            block_cost=self.blocks_needed,
            chunk_buckets=self.chunk_buckets,
            segment_buckets=self.segment_buckets,
            packed_prefill=e.packed_prefill,
            policy=self._policy)

        # device-resident slot state (touched from the host only at request
        # lifecycle events; the decode loop never reads it back)
        self.tables = jnp.zeros((e.max_slots, e.max_blocks_per_seq), jnp.int32)
        self.seq_lens = jnp.zeros((e.max_slots,), jnp.int32)
        self.active = jnp.zeros((e.max_slots,), bool)
        self.next_tok = jnp.zeros((e.max_slots,), jnp.int32)

        self._next_rid = 0
        self.requests: dict = {}        # rid -> Request (all ever submitted)

        if plan is None:
            (self._decode, self._prefill, self._copy_block, self._reset_slot,
             self._verify) = _cached_step_fns(cfg, _step_fn_key(self.ecfg))
        else:
            (self._decode, self._prefill, self._copy_block, self._reset_slot,
             self._verify) = _build_step_fns(cfg, self.ecfg, plan)
        if self.telemetry.enabled:
            # count unique trace keys per jitted step fn (the compiled-variant
            # metric the AOT warmup must hold at "declared set, counted up
            # front, zero new at serving time"); compile caching keeps
            # working — the wrapper only hashes arg shapes/dtypes
            wrap = self.telemetry.recompiles.wrap
            self._decode = wrap("decode", self._decode)
            self._prefill = wrap("prefill", self._prefill)
            self._copy_block = wrap("copy_block", self._copy_block)
            self._reset_slot = wrap("reset_slot", self._reset_slot)
            if self._verify is not None:
                self._verify = wrap("verify", self._verify)
        self._step_device_s = 0.0
        self._warmup_prefill()
        self._warmup_verify()

    def _warmup_prefill(self) -> None:
        """Drive every declared (chunk x segments) prefill bucket through the
        wrapped prefill fn once at construction, so steady-state serving
        never traces a new prefill variant. All-padding arguments (valids ==
        0, slot == max_slots sentinel) make every pool write a no-op — the
        donated pool round-trips bit-identical, only the executables and the
        recompile-tracker keys are created."""
        e = self.ecfg
        for c, g in self.prefill_grid:
            _, _, self.pool_state = self._device_call(
                "engine/warmup_prefill", self._prefill,
                self.params, self.pool_state, jnp.zeros((g, c), jnp.int32),
                self.tables, jnp.zeros((g,), jnp.int32),
                jnp.zeros((g,), jnp.int32),
                jnp.full((g,), e.max_slots, jnp.int32))

    def _warmup_verify(self) -> None:
        """Compile the (single) verify variant at construction, same
        all-padding trick as ``_warmup_prefill``: every slot inactive means
        qlims == 0 so every paged write drops and every recurrent slot keeps
        its old state — the donated pool round-trips bit-identical. Serving
        then never traces a new verify variant (the verify batch is always
        the full (max_slots, k) shape)."""
        if self._verify is None:
            return
        e = self.ecfg
        z = jnp.zeros((e.max_slots,), jnp.int32)
        _, _, _, _, self.pool_state = self._device_call(
            "engine/warmup_verify", self._verify,
            self.params, self.pool_state,
            jnp.zeros((e.max_slots, e.spec.k), jnp.int32), self.tables,
            z, jnp.zeros((e.max_slots,), bool), z)

    @property
    def stats(self) -> dict:
        """Back-compat snapshot of the registry-backed engine counters (the
        pre-telemetry ad-hoc dict keys). Read-only view — the full metric
        set lives in ``self.telemetry.registry``."""
        return {"decode_steps": self._m_decode_steps.value,
                "prefill_chunks": self._m_prefill_chunks.value,
                "emitted": self._m_emitted.value,
                "occupancy_sum": self._m_occupancy.value,
                "prefix_hit_tokens": self._m_prefix_hits.value,
                "cow_copies": self._m_cow.value,
                "preemptions": self._m_preempts.value,
                "resumes": self._m_resumes.value,
                "block_appends": self._m_appends.value}

    def bucket_dispatches(self) -> dict:
        """Serving-time prefill dispatch counts per declared (chunk_len,
        num_segments) bucket (warmup calls are not counted)."""
        return {k: int(m.value) for k, m in self._m_bucket.items()}

    # ----------------------------------------------------------------- API
    def blocks_needed(self, total_tokens: int) -> int:
        """Blocks one sequence of `total_tokens` reserves: the max over the
        per-kind provider costs (the block table is shared across layers)."""
        return SP.seq_blocks_needed(self.providers, total_tokens)

    def add_request(self, prompt, max_new: int, *, temperature: float = 0.0,
                    key=None, stop_token: Optional[int] = None,
                    priority: int = 0) -> int:
        """Queue a request; returns its id. `prompt`: 1-D int tokens.
        `priority` is the oversubscription class (LOWER is more important;
        ignored by the conservative scheduler).

        Validates up front that prompt + generation budget fits both the
        per-sequence block table and the whole pool, so infeasible requests
        fail here with the offending numbers instead of deep inside the
        scheduler."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.shape[0] < 1:
            raise ValueError("prompt must contain at least one token")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        e = self.ecfg
        total = prompt.shape[0] + max_new
        need = self.blocks_needed(total)
        if need > e.max_blocks_per_seq:
            raise ValueError(
                f"request infeasible: prompt_len {prompt.shape[0]} + max_new "
                f"{max_new} = {total} tokens needs {need} blocks > "
                f"max_blocks_per_seq {e.max_blocks_per_seq} "
                f"(= {e.max_blocks_per_seq * e.block_size} tokens at "
                f"block_size {e.block_size})")
        if need > e.num_blocks:
            raise ValueError(
                f"request infeasible: prompt_len {prompt.shape[0]} + max_new "
                f"{max_new} = {total} tokens needs {need} blocks > pool "
                f"budget num_blocks {e.num_blocks}")
        if temperature > 0.0 and key is None:
            key = jax.random.PRNGKey(self._next_rid)
        rid = self._next_rid
        self._next_rid += 1
        req = Request(
            rid=rid, prompt=prompt, max_new=max_new, temperature=temperature,
            key=key, stop_token=stop_token, priority=priority,
            arrive_t=self.telemetry.clock())
        self.requests[rid] = req
        self.scheduler.submit(req)
        self.telemetry.record(rid, "arrive", prompt_len=int(prompt.shape[0]),
                              max_new=int(max_new))
        return rid

    def _device_call(self, span: str, fn, *args):
        """Dispatch one jitted step under a labeled profiler span. In the
        timing path (`step_timing`) only, block on the results so the
        measured interval is device completion rather than async dispatch,
        and accumulate it into the current step's device time."""
        tel = self.telemetry
        if not tel.enabled:
            return fn(*args)
        with tel.span(span):
            if not tel.step_timing:
                return fn(*args)
            t0 = tel.clock()
            out = jax.block_until_ready(fn(*args))
            self._step_device_s += tel.clock() - t0
            return out

    def step(self) -> list:
        """One engine iteration: admit -> prefill chunk(s) -> batched decode.
        Under oversubscription the order becomes: priority preemption ->
        (policy-gated) admit + prefill -> per-sequence block growth (with
        victim preemption on append failure) -> batched decode. Returns the
        rids that emitted a token this step (token values are materialized
        lazily — read them via `drain()` / `output()`)."""
        e = self.ecfg
        tel = self.telemetry
        emitted = []
        self._step_device_s = 0.0
        t_step = tel.clock() if tel.step_timing else 0.0
        t_wall = tel.clock() if self._policy is not None else 0.0
        n_prefills = 0
        sync_memo = {}                  # one host transfer per step vector

        pol = self._policy
        if pol is not None and pol.cfg.priority_preemption:
            self._priority_preempt()
        allow_prefill = True
        if pol is not None:
            head_wait = None
            if self.scheduler.waiting:
                head = self.scheduler.waiting[0]
                if head.arrive_t is not None:
                    head_wait = pol.clock() - head.arrive_t
            decoding = sum(1 for r in self.scheduler.running.values()
                           if r.state == DECODING)
            allow_prefill = pol.allow_prefill(
                head_wait_s=head_wait, decoding=decoding,
                pool_util=self.block_pool.utilization)
            if not allow_prefill:
                self._m_prefill_deferrals.inc()

        admitted = self.scheduler.admit() if allow_prefill else []
        for req in admitted:
            row = self.block_pool.table(req.rid)
            padded = np.zeros((e.max_blocks_per_seq,), np.int32)
            padded[:len(row)] = row
            self.tables = self.tables.at[req.slot].set(jnp.asarray(padded))
            if self._has_recurrent:
                # the slot's recurrent slab rows still hold the previous
                # occupant's final state — zero them for the newcomer
                self.pool_state = self._device_call(
                    "engine/reset_slot", self._reset_slot,
                    self.pool_state, jnp.int32(req.slot))
            self._m_prefix_hits.inc(req.prefilled)
            resumed = req.preempts > 0
            if tel.enabled:
                t_admit = tel.record(req.rid, "resume" if resumed else "admit",
                                     slot=req.slot)
                if not resumed:
                    t_arrive = tel.tracer.first(req.rid, "arrive")
                    if t_arrive is not None:
                        self._h_queue_wait.observe(t_admit - t_arrive)
                if req.prefilled:
                    tel.record(req.rid, "prefix_hit", tokens=req.prefilled,
                               blocks=req.shared_blocks
                               + (1 if req.cow_src is not None else 0))
            if resumed:
                self._m_resumes.inc()
            if req.snapshot is not None and self._snapshot_resume:
                # pure-recurrent resume: scatter the checkpointed slab rows
                # back into the (freshly zeroed) slot and skip the re-scan —
                # prefill only covers the tokens past the snapshot
                self.pool_state = {
                    f"l{i}": p.resume_restore(
                        self.pool_state[f"l{i}"], req.slot, req.snapshot[i])
                    for i, p in enumerate(self.providers)}
                req.prefilled = req.snapshot_len
            req.snapshot = None
            req.snapshot_len = 0
            self.seq_lens = self.seq_lens.at[req.slot].set(req.prefilled)
            if req.cow_src is not None:
                # whole prefill cached: copy the last matched block into the
                # private block at its table position, then re-prefill only
                # the final token there (yields the first-token logits)
                dst = row[req.prefill_len // e.block_size - 1]
                self.pool_state = self._device_call(
                    "engine/copy_block", self._copy_block,
                    self.pool_state, jnp.int32(req.cow_src), jnp.int32(dst))
                self._m_cow.inc()

        for batch in (self.scheduler.next_prefills() if allow_prefill
                      else []):
            # one segment-masked device call per batch: segment j carries
            # request j's chunk, padded to the (C, G) bucket; missing
            # segments get valid=0 and the out-of-range slot sentinel
            C, G = batch.chunk_len, batch.num_segments
            tokens = np.zeros((G, C), np.int32)
            starts = np.zeros((G,), np.int32)
            valids = np.zeros((G,), np.int32)
            slots = np.full((G,), e.max_slots, np.int32)
            for j, (req, start, valid) in enumerate(batch.segments):
                tokens[j, :valid] = req.prefill_src[start:start + valid]
                starts[j], valids[j], slots[j] = start, valid, req.slot
            greedy, logits, self.pool_state = self._device_call(
                "engine/prefill", self._prefill,
                self.params, self.pool_state, jnp.asarray(tokens),
                self.tables, jnp.asarray(starts), jnp.asarray(valids),
                jnp.asarray(slots))
            self._m_bucket[(C, G)].inc()
            for j, (req, start, valid) in enumerate(batch.segments):
                req.prefilled += valid
                self.scheduler.register_prefilled(req)
                self.seq_lens = self.seq_lens.at[req.slot].set(req.prefilled)
                self._m_prefill_chunks.inc()
                n_prefills += 1
                tel.record(req.rid, "prefill_chunk", start=start, tokens=valid)
                if req.prefilled == req.prefill_len:
                    # prefill complete: segment j's logits yield the next
                    # token (the request's FIRST, unless this is a resumed
                    # re-prefill continuing an interrupted generation)
                    self._record_token(req, greedy, j, logits, j, sync_memo)
                    emitted.append(req.rid)
                    if tel.enabled:
                        if req.got_first:
                            tel.record(req.rid, "decode_token")
                        else:
                            t_first = tel.record(req.rid, "first_token")
                            t_arrive = tel.tracer.first(req.rid, "arrive")
                            if t_arrive is not None:
                                self._h_ttft.observe(t_first - t_arrive)
                    req.got_first = True
                    req.state = DECODING
                    self.active = self.active.at[req.slot].set(True)
                    if req.done:
                        self._finish(req)

        if pol is not None:
            self._grow_decode()
        batch = self.scheduler.decode_batch()
        if batch and self._verify is not None:
            emitted.extend(self._spec_decode(batch, sync_memo))
        elif batch:
            greedy, logits, self.seq_lens, self.pool_state = self._device_call(
                "engine/decode", self._decode,
                self.params, self.pool_state, self.next_tok, self.tables,
                self.seq_lens, self.active)
            self.next_tok = greedy
            self._m_decode_steps.inc()
            self._m_occupancy.inc(len(batch) / e.max_slots)
            for req in batch:
                self._record_token(req, greedy, req.slot, logits, req.slot,
                                   sync_memo)
                emitted.append(req.rid)
                tel.record(req.rid, "decode_token")
                if req.done:
                    self._finish(req)

        self._m_emitted.inc(len(emitted))
        if tel.enabled:
            self._g_waiting.set(len(self.scheduler.waiting))
            self._g_running.set(len(self.scheduler.running))
            self._g_free_blocks.set(self.block_pool.num_free)
            if tel.step_timing:
                total = tel.clock() - t_step
                tel.record_step(
                    host_s=total - self._step_device_s,
                    device_s=self._step_device_s, prefills=n_prefills,
                    decode_batch=len(batch), emitted=len(emitted))
        if pol is not None:
            pol.note_step(tel.clock() - t_wall)
        return emitted

    def drain(self, max_steps: int = 100_000) -> dict:
        """Run steps until every queued request finished; returns
        {rid: np.ndarray of generated tokens} for ALL finished requests."""
        steps = 0
        while self.scheduler.has_work:
            if steps >= max_steps:      # permit exactly max_steps steps
                raise RuntimeError("drain did not converge")
            self.step()
            steps += 1
        memo = {}                       # one transfer per unique step vector
        return {rid: self._materialize(r, memo)
                for rid, r in self.requests.items() if r.state == FINISHED}

    def output(self, rid) -> np.ndarray:
        """Materialize a request's generated tokens (blocks on the device)."""
        return self._materialize(self.requests[rid], {})

    def _materialize(self, req: Request, memo: dict) -> np.ndarray:
        out = []
        for t in req.out_tokens:
            if isinstance(t, tuple):                # (step vector, index)
                vec, i = t
                host = memo.get(id(vec))
                if host is None:
                    host = memo[id(vec)] = np.asarray(vec)
                out.append(int(host[i]))
            else:
                out.append(int(t))
        return np.asarray(out, np.int32)

    def defragment(self) -> np.ndarray:
        """Compact used KV blocks to the front of the pool and rewrite every
        live block table (host bookkeeping + one device gather per pool).
        Shared (prefix-cached) blocks move once and every owner's table
        follows; cached-free blocks keep their content. Each layer's state
        provider applies the permutation its own way (paged pools gather on
        the block axis; recurrent slabs are slot-indexed and untouched).
        Returns the applied permutation `src`
        (``new_pool[i] = old_pool[src[i]]``)."""
        src = self.block_pool.defragment()
        self._m_defrags.inc()
        self.telemetry.record(None, "defrag",
                              moved=int(np.sum(src != np.arange(len(src)))))
        src_j = jnp.asarray(src)
        self.pool_state = {
            f"l{i}": p.defrag_remap(self.pool_state[f"l{i}"], src_j)
            for i, p in enumerate(self.providers)}
        tables = np.zeros(self.tables.shape, np.int32)
        for req in self.scheduler.running.values():
            row = self.block_pool.table(req.rid)
            tables[req.slot, :len(row)] = row
        self.tables = jnp.asarray(tables)
        return src

    # -------------------------------------------------- preemption internals
    def _grow_decode(self) -> None:
        """Optimistic growth: append the block(s) each decoding sequence's
        next KV write needs, strongest request first (the policy's
        protection order). When the pool can't satisfy an append, preempt
        strictly-WEAKER victims until it can — and if none exist, the
        growing request itself is the weakest and rolls back. The maximal
        request is never victimized while anything weaker runs, so progress
        is guaranteed (its full span fits the pool, validated at submit)."""
        sched = self.scheduler
        order = sorted(sched.decode_batch(), key=SLOPolicy.protection_key)
        for req in order:
            if req.rid not in sched.running:
                continue                # became a victim earlier this pass
            need = sched.growth_need(req, extra=self._spec_horizon(req))
            if need == 0:
                continue
            while not self.block_pool.can_alloc(need):
                me = SLOPolicy.protection_key(req)
                victim = self._policy.pick_victim(
                    [r for r in sched.running.values()
                     if r is not req and SLOPolicy.protection_key(r) > me])
                self._preempt(victim if victim is not None else req)
                if victim is None:
                    break
            if req.rid in sched.running:
                fresh = sched.grow(req, extra=self._spec_horizon(req))
                old = len(self.block_pool.table(req.rid)) - len(fresh)
                self.tables = self.tables.at[
                    req.slot, old:old + len(fresh)].set(
                        jnp.asarray(fresh, jnp.int32))
                self._m_appends.inc(len(fresh))

    def _priority_preempt(self) -> None:
        """A blocked queue head may evict strictly-lower-class victims: while
        the head cannot be admitted and such a victim runs, preempt the
        weakest one. Equal-or-higher-class work is never disturbed, so this
        terminates and never inverts the class order."""
        sched = self.scheduler
        while sched.waiting and not sched.can_admit_head():
            head = sched.waiting[0]
            victim = self._policy.pick_victim(
                list(sched.running.values()), max_priority=head.priority)
            if victim is None:
                return
            self._preempt(victim)

    def _preempt(self, req: Request) -> None:
        """Evict one running request and roll it back to WAITING. Host-side
        order matters: materialize its lazy token refs (the step vectors are
        unreachable after the slot turns over), snapshot recurrent slabs if
        every provider supports restore, deactivate the slot, then let the
        scheduler register + free its blocks and requeue it. Materialization
        uses a private memo: this call drops the victim's step-vector refs,
        so a shared id()-keyed memo could dangle for the rest of the step."""
        req.out_tokens = [int(t) for t in self._materialize(req, {})]
        if self._snapshot_resume:
            # state covers exactly the tokens processed as inputs so far:
            # seq_tokens - 1 while DECODING (the last generated token is the
            # pending input), prefilled while mid-prefill
            req.snapshot = [
                p.preempt_checkpoint(self.pool_state[f"l{i}"], req.slot)
                for i, p in enumerate(self.providers)]
            req.snapshot_len = (req.seq_tokens - 1 if req.state == DECODING
                                else req.prefilled)
        self.active = self.active.at[req.slot].set(False)
        blocks = len(self.block_pool.table(req.rid))
        if self.drafter is not None:
            self.drafter.forget(req.rid)
        self.scheduler.preempt(req)
        self._m_preempts.inc()
        self.telemetry.record(req.rid, "preempt",
                              generated=len(req.out_tokens), blocks=blocks)

    def preempt_request(self, rid: int) -> bool:
        """Force-preempt one running request (test/ops hook — the soak tests
        drive every request through at least one evict/resume cycle with
        this). Returns False if the request isn't currently running."""
        req = self.requests[rid]
        if req.state not in (PREFILLING, DECODING):
            return False
        self._preempt(req)
        return True

    # ------------------------------------------------------------- internal
    def _spec_decode(self, batch: list, sync_memo: dict) -> list:
        """One speculative decode step over the DECODING batch: host
        drafting, ONE jitted verify dispatch covering k tokens per slot,
        then a host sync of the (greedy, accepts) pair to record each
        accepted run. Spec mode inherently syncs every step — acceptance
        decides how many tokens exist, so lazy step-vector refs can't
        represent the output — which is why verify must emit > 1 token per
        step on average to win.

        Per slot the verify row is ``[pending, d1 .. d_{k-1}]``: the last
        emitted (true) token plus the drafter's guesses for the next k-1
        stream positions. ``qlims`` caps accepted tokens AND KV writes at
        what the request may still emit, so writes never pass the block
        reservation; temperature requests run with qlims == 1 (one
        guaranteed token whose value the host samples — the device only
        commits the pending token's KV, which is correct regardless of the
        sampled value)."""
        e = self.ecfg
        tel = self.telemetry
        k = e.spec.k
        emitted = []
        tokens = np.zeros((e.max_slots, k), np.int32)
        qlims = np.zeros((e.max_slots,), np.int32)
        plans = []
        for req in batch:
            # drafting needs the concrete stream: materialize any lazy
            # step-vector refs (at most this step's prefill-completion token)
            if any(isinstance(t, tuple) for t in req.out_tokens):
                req.out_tokens = [int(t) for t in
                                  self._materialize(req, sync_memo)]
            q = (1 if req.temperature > 0.0
                 else min(k, req.max_new - len(req.out_tokens)))
            ctx = np.concatenate([req.prompt,
                                  np.asarray(req.out_tokens, np.int32)])
            tokens[req.slot, 0] = ctx[-1]
            if q > 1:
                tokens[req.slot, 1:] = self.drafter.propose(
                    req.rid, ctx, k - 1)
            qlims[req.slot] = q
            plans.append((req, q))
        greedy, accepts, logits, self.seq_lens, self.pool_state = \
            self._device_call(
                "engine/verify", self._verify,
                self.params, self.pool_state, jnp.asarray(tokens),
                self.tables, self.seq_lens, self.active, jnp.asarray(qlims))
        g_host = np.asarray(greedy)
        a_host = np.asarray(accepts)
        self._m_step_syncs.inc()
        self._m_decode_steps.inc()
        self._m_verify_steps.inc()
        self._m_occupancy.inc(len(batch) / e.max_slots)
        for req, q in plans:
            a = int(a_host[req.slot])
            toks = [int(t) for t in g_host[req.slot, :a]]
            if req.temperature > 0.0:
                req.key, sub = jax.random.split(req.key)
                toks = [int(jax.random.categorical(
                    sub, logits[req.slot, 0] / req.temperature))]
            if req.stop_token is not None and req.stop_token in toks:
                # truncate at the stop token; the device advanced past it
                # but the slot is freed below, so the overrun is unreachable
                toks = toks[:toks.index(req.stop_token) + 1]
            req.out_tokens.extend(toks)
            emitted.append(req.rid)
            drafted, accepted = max(q - 1, 0), max(a - 1, 0)
            self._m_draft.inc(drafted)
            self._m_accepted.inc(accepted)
            if drafted:
                self._h_accept.observe(accepted / drafted)
            tel.record(req.rid, "verify", drafted=drafted, accepted=accepted)
            tel.record(req.rid, "decode_token", tokens=len(toks))
            self._m_emitted.inc(len(toks) - 1)    # step() adds 1 per rid
            if req.done:
                self._finish(req)
        return emitted

    def _spec_horizon(self, req: Request) -> int:
        """Extra block-growth horizon under speculation: the next verify
        step writes KV at positions ``seq_tokens-1 .. seq_tokens-2+qlims``,
        i.e. qlims-1 tokens past what the one-token decode step writes."""
        if self._verify is None or req.temperature > 0.0:
            return 0
        return min(self.ecfg.spec.k, req.max_new - len(req.out_tokens)) - 1

    def _record_token(self, req: Request, greedy_vec, greedy_idx,
                      logits, logits_idx, sync_memo: dict):
        """Record the request's next token. Greedy requests store a
        (step-vector, index) ref — no host sync; temperature / stop_token
        requests pay a host round-trip for the concrete value. `sync_memo`
        (one dict per engine step) caches materialized step vectors so
        stop_token scanning costs at most ONE transfer per step vector, not
        one per request."""
        if req.temperature > 0.0:
            req.key, sub = jax.random.split(req.key)
            tok = int(jax.random.categorical(
                sub, logits[logits_idx] / req.temperature))
            self.next_tok = self.next_tok.at[req.slot].set(tok)
            req.out_tokens.append(tok)
            return
        if req.stop_token is not None:
            host = sync_memo.get(id(greedy_vec))
            if host is None:
                host = sync_memo[id(greedy_vec)] = np.asarray(greedy_vec)
                self._m_step_syncs.inc()
            tok = int(host[greedy_idx])
            req.out_tokens.append(tok)
        else:
            req.out_tokens.append((greedy_vec, greedy_idx))
        if req.state != DECODING:
            # token came from prefill logits: seed the device next-token
            # vector for the upcoming decode step
            self.next_tok = self.next_tok.at[req.slot].set(
                greedy_vec[greedy_idx])

    def _finish(self, req: Request) -> None:
        self.active = self.active.at[req.slot].set(False)
        if self.drafter is not None:
            self.drafter.forget(req.rid)
        self.scheduler.finish(req)
        tel = self.telemetry
        if tel.enabled:
            t_fin = tel.record(req.rid, "finish",
                               generated=len(req.out_tokens))
            t_arrive = tel.tracer.first(req.rid, "arrive")
            if t_arrive is not None:
                self._h_e2e.observe(t_fin - t_arrive)
