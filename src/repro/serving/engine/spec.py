"""Speculative decoding for the paged serving engine.

The paper's work-depth lens (§4): decode is a sequential-depth bottleneck on
memory-bound hardware, so spend redundant parallel work — verify K draft
tokens in ONE multi-query attention sweep — to cut depth by the accepted run
length. The pieces:

  * ``SpecConfig`` — engine-facing knob (``EngineConfig.spec``). Only ``k``
    affects traced shapes; the drafter is host-only state.
  * ``Drafter`` protocol + implementations. Drafting is pure host work
    between device steps: ``propose(rid, context, n)`` guesses the next n
    tokens of a request's stream given every token known so far
    (prompt ++ emitted). ``NgramDrafter`` is the self-drafting
    prompt-lookahead default (no second model); ``DraftModelDrafter`` runs a
    small config's greedy continuation; ``ReplayDrafter`` replays known
    continuations (the high-acceptance limit, used by benchmarks).
  * ``verify_step`` — the pure function the engine jits: embed the K draft
    tokens, run the multi-query verify through every layer
    (``transformer.paged_verify_step``), compute the greedy acceptance run
    in-jit, and roll recurrent slabs back to the accepted checkpoint
    (``state_providers.select_checkpoint``). Paged KV needs no rollback
    dispatch: writes beyond the per-slot ``qlims`` horizon are dropped, and
    every next verify step rewrites the positions a rejection left stale —
    masked in the interim by each query's causal bound — so pool contents
    stay canonical for the committed prefix.

Acceptance rule (greedy): verify feeds ``[pending, d1 .. d_{K-1}]`` where
``pending`` is the last emitted (true) token and ``d_i`` are draft guesses.
With greedy outputs ``g_0 .. g_{K-1}``, the step emits ``g_0 .. g_{a-1}``
where ``a - 1`` is the longest prefix with ``d_i == g_{i-1}`` — one
guaranteed token plus every verified guess, so a ranges 1..K and greedy
streams are bit-identical to one-token-at-a-time decoding.

Draft state (the per-request lookahead cursors) lives ONLY here, in each
drafter's ``_draft_state`` — the repo lint bans touching it from anywhere
else, mirroring how checkpointed recurrent state stays inside
state_providers.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro.models import state_providers as SP
from repro.models import transformer as T


# ------------------------------------------------------------------ drafters
@runtime_checkable
class Drafter(Protocol):
    """Host-side draft-token source. ``context`` is every token of the
    request's stream known so far (prompt ++ emitted outputs, 1-D int
    array); ``propose`` returns exactly ``n`` int32 guesses for the next n
    stream positions. ``forget`` drops any per-request state (request
    finished or preempted — its stream may be re-drafted from scratch)."""

    def propose(self, rid: int, context, n: int) -> np.ndarray: ...

    def forget(self, rid: int) -> None: ...


class NgramDrafter:
    """Self-drafting n-gram / prompt-lookahead: find the most recent earlier
    occurrence of the stream's current n-gram suffix and propose the tokens
    that followed it. No second model — on copy-/template-heavy streams the
    continuation has literally been seen before. Falls back to repeating
    the last token (still verified, so wrong guesses only cost acceptance).

    ``_draft_state[rid]`` caches the source cursor of the last match so an
    accepted run keeps streaming from the same earlier span without
    re-scanning."""

    def __init__(self, n: int = 3):
        if n < 1:
            raise ValueError(f"ngram order must be >= 1, got {n}")
        self.n = int(n)
        self._draft_state: dict = {}

    def _match_at(self, ctx, src: int, m: int) -> bool:
        return src >= m and np.array_equal(ctx[src - m:src], ctx[len(ctx) - m:])

    def propose(self, rid, context, n):
        ctx = np.asarray(context)
        L = len(ctx)
        out = np.full((n,), int(ctx[-1]), np.int32)
        m = min(self.n, L - 1)
        if m < 1:
            return out
        src = None
        hint = self._draft_state.get(rid)
        if hint is not None and hint < L and self._match_at(ctx, hint, m):
            src = hint
        if src is None:
            pat = ctx[L - m:]
            for e in range(L - 2, m - 2, -1):     # newest earlier match wins
                if e - m + 1 < 0:
                    break
                if np.array_equal(ctx[e - m + 1:e + 1], pat):
                    src = e + 1
                    break
        if src is None:
            self._draft_state.pop(rid, None)
            return out
        take = ctx[src:src + n]
        out[:len(take)] = take
        self._draft_state[rid] = src + n          # cursor if fully accepted
        return out

    def forget(self, rid):
        self._draft_state.pop(rid, None)


class DraftModelDrafter:
    """Draft with a small model config's greedy continuation. Reference-grade:
    each call re-prefills the full context through ``serve.generate`` —
    correct and simple, but the n-gram drafter is the fast path. The draft
    model needs nothing in common with the target beyond the vocab."""

    def __init__(self, cfg, params):
        self.cfg = cfg
        self.params = params
        self._draft_state: dict = {}

    def propose(self, rid, context, n):
        from repro.serving import serve   # lazy: serve imports this package
        out = serve.generate(self.cfg, self.params,
                             jnp.asarray(np.asarray(context))[None],
                             max_new=n, temperature=0.0)
        return np.asarray(out)[0].astype(np.int32)

    def forget(self, rid):
        self._draft_state.pop(rid, None)


class ReplayDrafter:
    """Oracle drafter replaying known continuations — the high-acceptance
    limit of a perfectly aligned draft model. Benchmarks use it to measure
    the verify path's ceiling: record each request's expected stream
    (prompt ++ reference output) with ``remember``, then every proposal is
    the true continuation and acceptance approaches 1."""

    def __init__(self):
        self._draft_state: dict = {}

    def remember(self, rid, stream):
        self._draft_state[rid] = np.asarray(stream, np.int32)

    def propose(self, rid, context, n):
        out = np.full((n,), int(np.asarray(context)[-1]), np.int32)
        full = self._draft_state.get(rid)
        L = len(context)
        if full is not None and L < len(full):
            take = full[L:L + n]
            out[:len(take)] = take
        return out

    def forget(self, rid):
        pass    # streams survive preemption; resume re-drafts from them


# ------------------------------------------------------------------- config
@dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knob for ``EngineConfig.spec``.

    k        — tokens fed to each verify step: 1 pending (true) token plus
               k-1 draft guesses; each step advances a slot by 1..k tokens.
               Only this field affects traced shapes.
    drafter  — "ngram" (default) or any ``Drafter`` instance.
    ngram    — suffix order for the built-in n-gram drafter."""
    k: int = 4
    drafter: object = "ngram"
    ngram: int = 3

    def __post_init__(self):
        if not 2 <= self.k <= 32:
            raise ValueError(f"spec k must be in [2, 32], got {self.k}")
        if isinstance(self.drafter, str):
            if self.drafter != "ngram":
                raise ValueError(f"unknown drafter name {self.drafter!r}")
        elif not isinstance(self.drafter, Drafter):
            raise TypeError("drafter must be 'ngram' or implement "
                            "propose/forget (the Drafter protocol)")
        if self.ngram < 1:
            raise ValueError(f"ngram order must be >= 1, got {self.ngram}")

    def build_drafter(self) -> Drafter:
        if isinstance(self.drafter, str):
            return NgramDrafter(self.ngram)
        return self.drafter


# -------------------------------------------------------------- verify step
def verify_step(cfg, params, pool, tokens, block_tables, seq_lens, active,
                qlims, *, impl="ref", interpret=None):
    """One speculative verify step over the full slot batch (pure; the
    engine jits it with the pool donated).

    tokens:   (B, K) int32 — ``[pending, d1 .. d_{K-1}]`` per slot; draft j
              sits at absolute position ``seq_lens[b] + j``.
    seq_lens: (B,) tokens already processed per slot (0-padded rows ignored
              via ``active``).
    qlims:    (B,) accept/write horizon: ``min(K, tokens the request may
              still emit)`` — caps both the KV writes (never past the
              sequence's block reservation) and the accepted count. 0 for
              inactive slots.

    Returns (greedy (B, K), accepts (B,), logits (B, K, V),
    new_seq_lens (B,), new pool). ``accepts`` is 0 for inactive slots,
    else 1..qlims; slot state (paged KV, ring cursors implied by seq_lens,
    recurrent slabs) advances by exactly ``accepts`` tokens."""
    base = jnp.where(active, seq_lens, 0)
    qlims = jnp.where(active, qlims, 0)
    lg, aux = T.paged_verify_step(cfg, params, pool, tokens, block_tables,
                                  base, qlims, impl=impl, interpret=interpret)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)            # (B, K)
    match = (tokens[:, 1:] == greedy[:, :-1]).astype(jnp.int32)   # (B, K-1)
    run = jnp.cumprod(match, axis=1) if match.shape[1] else match
    accepts = 1 + jnp.sum(run, axis=1)
    accepts = jnp.minimum(accepts, qlims)                         # 0 if inactive

    new_pool = {}
    for i, sk in enumerate(SP.state_kinds(cfg)):
        name = f"l{i}"
        if sk in ("full", "ring"):
            new_pool[name] = aux[name]
        else:
            new_pool[name] = SP.select_checkpoint(aux[name], accepts,
                                                  pool[name])
    new_seq_lens = seq_lens + accepts
    return greedy, accepts, lg, new_seq_lens, new_pool
