"""Paged KV cache bookkeeping: a fixed pool of fixed-size blocks plus a
per-sequence block table (vLLM-style PagedAttention memory management).

`BlockPool` is pure host-side accounting — the device-side pool tensors live
in the Engine (`models.transformer.init_paged_state`). Allocation is O(1)
free-list pop; every block is owned by at most one sequence; `defragment`
computes a compaction permutation the Engine applies to the device pools so
long-running servers keep used blocks dense at the front of the pool.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


class BlockPoolError(RuntimeError):
    """Invariant violation: double free, unknown owner, over-allocation."""


@dataclass
class BlockPool:
    num_blocks: int
    block_size: int
    _free: list = field(init=False)
    _owned: dict = field(init=False)      # rid -> ordered list of block ids

    def __post_init__(self):
        self._free = list(range(self.num_blocks - 1, -1, -1))  # LIFO
        self._owned = {}

    # ------------------------------------------------------------- queries
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def utilization(self) -> float:
        return 1.0 - self.num_free / self.num_blocks

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_alloc(self, n_blocks: int) -> bool:
        return n_blocks <= self.num_free

    def table(self, rid) -> list:
        """Ordered block ids of a sequence (logical page i -> physical id)."""
        if rid not in self._owned:
            raise BlockPoolError(f"unknown sequence {rid!r}")
        return list(self._owned[rid])

    # ----------------------------------------------------------- mutation
    def alloc(self, rid, n_blocks: int) -> list:
        """Append `n_blocks` fresh blocks to sequence `rid` (creating it)."""
        if n_blocks > self.num_free:
            raise BlockPoolError(
                f"need {n_blocks} blocks, only {self.num_free} free")
        got = [self._free.pop() for _ in range(n_blocks)]
        self._owned.setdefault(rid, []).extend(got)
        return got

    def free_seq(self, rid) -> int:
        """Release every block of a sequence. Double-free raises."""
        if rid not in self._owned:
            raise BlockPoolError(f"double free / unknown sequence {rid!r}")
        blocks = self._owned.pop(rid)
        self._free.extend(reversed(blocks))
        return len(blocks)

    def defragment(self) -> np.ndarray:
        """Compact used blocks to the front of the pool.

        Returns `src` (num_blocks,) int32 such that the device pools must be
        permuted as ``new_pool[i] = old_pool[src[i]]``; owner tables are
        rewritten in place to the new dense ids."""
        src = np.empty(self.num_blocks, np.int32)
        nxt = 0
        for rid in self._owned:
            new_ids = []
            for old in self._owned[rid]:
                src[nxt] = old
                new_ids.append(nxt)
                nxt += 1
            self._owned[rid] = new_ids
        n_used = nxt
        leftover = sorted(self._free)
        for old in leftover:
            src[nxt] = old
            nxt += 1
        self._free = list(range(self.num_blocks - 1, n_used - 1, -1))
        return src
