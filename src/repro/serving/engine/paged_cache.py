"""Paged KV cache bookkeeping: a fixed pool of fixed-size blocks, per-sequence
block tables, per-block refcounts, and a prefix index for cross-request KV
reuse (vLLM-style PagedAttention memory management with prefix caching).

`BlockPool` is pure host-side accounting — the device-side pool tensors live
in the Engine (`models.transformer.init_paged_state`). The pool is
state-kind agnostic: how many blocks a sequence charges is the caller's
policy (the scheduler's provider-aware `block_cost` — full attention pages
O(S), sliding-window rings cap at ceil(window/bs)+1, recurrent sequences
own zero blocks, `alloc(rid, 0)` just registers the owner so `table` /
`free_seq` stay uniform). A block may be referenced by any number of
sequence tables (shared read-only prompt prefixes); the refcount tracks
exactly how many. Blocks whose refcount drops
to zero but that are registered in the prefix index are NOT lost: they go on
the free list in least-recently-used order with their device content intact,
so a later request with the same prompt prefix can revive them via
`match_prefix` + `share` — and allocation pressure reclaims them LRU-first
(eviction = popping a registered block off the free list). With an empty
index the pool degrades exactly to the PR 1 allocator.

Free-list discipline (one deque encodes both the reuse preference and the
eviction order):

    appendleft: cached blocks          append/pop (right): plain blocks
    [newest cached ... oldest cached | never used | recently freed plain]
                                                        ^ alloc pops here

Plain (unregistered) frees are reused first; registered blocks are only
reclaimed once no plain block remains, oldest-freed first (LRU).

`defragment` computes a compaction permutation the Engine applies to the
device pools; it rewrites every owner's table consistently under aliasing
(a shared block moves once, every table follows) and preserves the content
and LRU order of cached-free blocks.
"""
from __future__ import annotations

import hashlib
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.serving.telemetry import MetricsRegistry


class BlockPoolError(RuntimeError):
    """Invariant violation: double free, unknown owner, over-allocation."""


def prefix_hashes(tokens, block_size: int) -> list:
    """Chained digests, one per FULL block of `tokens`: hashes[i] commits to
    tokens[0 : (i+1)*block_size], so equal hashes imply equal token prefixes
    (up to digest collision) and therefore bitwise-equal KV content."""
    t = np.ascontiguousarray(np.asarray(tokens, np.int32).reshape(-1))
    out, h = [], b""
    for i in range(t.shape[0] // block_size):
        blk = t[i * block_size:(i + 1) * block_size].tobytes()
        h = hashlib.blake2b(h + blk, digest_size=16).digest()
        out.append(h)
    return out


@dataclass
class BlockPool:
    num_blocks: int
    block_size: int
    # metrics go through a telemetry registry (the Engine passes its own so
    # pool counters land in the same snapshot); on_evict lets the owner
    # record an `evict` lifecycle event per reclaimed cached block
    registry: Optional[MetricsRegistry] = None
    on_evict: Optional[Callable[[int], None]] = None
    _free: deque = field(init=False)
    _ref: list = field(init=False)        # block id -> refcount
    _owned: dict = field(init=False)      # rid -> ordered list of block ids
    _index: dict = field(init=False)      # prefix hash -> block id
    _hash_of: dict = field(init=False)    # block id -> prefix hash (inverse)

    def __post_init__(self):
        self._free = deque(range(self.num_blocks - 1, -1, -1))  # pops 0 first
        self._ref = [0] * self.num_blocks
        self._owned = {}
        self._index = {}
        self._hash_of = {}
        self._n_cached_free = 0         # registered blocks on the free list
        if self.registry is None:
            self.registry = MetricsRegistry()
        reg = self.registry
        self._m_lookups = reg.counter(
            "pool_prefix_lookups_total", "prefix-index lookups at admission")
        self._m_hit_blocks = reg.counter(
            "pool_prefix_hit_blocks_total", "cached blocks matched at admission")
        self._m_evictions = reg.counter(
            "pool_evictions_total", "cached-free blocks reclaimed (LRU)")
        self._m_registrations = reg.counter(
            "pool_registrations_total", "blocks published to the prefix index")

    @property
    def stats(self) -> dict:
        """Back-compat snapshot of the registry-backed pool counters (the
        pre-telemetry ad-hoc dict keys). Read-only view: mutate through the
        counters, never through this dict."""
        return {"lookups": self._m_lookups.value,
                "hit_blocks": self._m_hit_blocks.value,
                "evictions": self._m_evictions.value,
                "registrations": self._m_registrations.value}

    def note_prefix_lookup(self, hit_blocks: int) -> None:
        """Record one admission-time prefix lookup that matched `hit_blocks`
        cached blocks (the scheduler calls this only on the attempt that
        admits, so a blocked head request doesn't skew hit rates)."""
        self._m_lookups.inc()
        self._m_hit_blocks.inc(hit_blocks)

    # ------------------------------------------------------------- queries
    @property
    def num_free(self) -> int:
        """Allocatable blocks. Includes refcount-zero cached blocks — they
        hold reusable content but are reclaimed on demand (LRU)."""
        return len(self._free)

    @property
    def num_cached_free(self) -> int:
        """Refcount-zero blocks kept only for their prefix-index content.
        O(1): a maintained counter (validated against a full scan in
        `check()`), not a deque scan."""
        return self._n_cached_free

    @property
    def utilization(self) -> float:
        return 1.0 - self.num_free / self.num_blocks

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_alloc(self, n_blocks: int) -> bool:
        return n_blocks <= self.num_free

    def admit_feasible(self, shared: list, n_fresh: int) -> bool:
        """Can a request alias `shared` (possibly reviving cached-free
        blocks) AND still allocate `n_fresh` fresh blocks?"""
        return n_fresh <= len(self._free) - self.revive_count(shared)

    def revive_count(self, shared: list) -> int:
        """How many of `shared` are cached-FREE (would be revived off the
        free list by `share`, consuming free capacity) as opposed to live.
        Admission policies need the split: revived blocks count against the
        free list but carry content, fresh blocks are the true new demand."""
        return sum(1 for b in shared if self._ref[b] == 0)

    def table(self, rid) -> list:
        """Ordered block ids of a sequence (logical page i -> physical id)."""
        if rid not in self._owned:
            raise BlockPoolError(f"unknown sequence {rid!r}")
        return list(self._owned[rid])

    # ----------------------------------------------------------- mutation
    def alloc(self, rid, n_blocks: int) -> list:
        """Append `n_blocks` fresh private blocks to sequence `rid` (creating
        it). `n_blocks == 0` is valid and registers `rid` with an empty
        table (recurrent-only sequences own no blocks but still free
        uniformly). Popping a cached-free block evicts its prefix-index
        entry."""
        if n_blocks > len(self._free):
            raise BlockPoolError(
                f"need {n_blocks} blocks, only {len(self._free)} free")
        got = []
        for _ in range(n_blocks):
            b = self._free.pop()
            if b in self._hash_of:                      # LRU eviction
                del self._index[self._hash_of.pop(b)]
                self._n_cached_free -= 1
                self._m_evictions.inc()
                if self.on_evict is not None:
                    self.on_evict(b)
            self._ref[b] = 1
            got.append(b)
        self._owned.setdefault(rid, []).extend(got)
        return got

    def append(self, rid, n_blocks: int) -> list:
        """On-demand growth: append `n_blocks` fresh blocks to an EXISTING
        sequence (the oversubscription per-step decode append). Unlike
        `alloc` this never creates an owner — growing a sequence the pool
        has never seen is a bookkeeping bug, not a request."""
        if rid not in self._owned:
            raise BlockPoolError(f"append to unknown sequence {rid!r}")
        return self.alloc(rid, n_blocks)

    def evict_seq(self, rid) -> int:
        """Victim eviction: release every block of a preempted sequence.
        Identical accounting to `free_seq` — callers register the victim's
        fully written prefix blocks FIRST, so refcount-zero registered
        blocks park on the cold end of the free list content-intact and the
        victim's resume can alias them back instead of recomputing."""
        return self.free_seq(rid)

    def share(self, rid, blocks: list) -> None:
        """Alias existing blocks into `rid`'s table (refcount +1 each).
        Blocks must be live (ref > 0) or cached in the prefix index; a
        cached-free block is revived off the free list, content intact."""
        if len(set(blocks)) != len(blocks):
            raise BlockPoolError("share called with duplicate blocks")
        row = self._owned.get(rid, [])
        for b in blocks:                                # validate, no mutation
            if not (0 <= b < self.num_blocks):
                raise BlockPoolError(f"share of invalid block {b}")
            if self._ref[b] == 0 and b not in self._hash_of:
                raise BlockPoolError(f"share of free uncached block {b}")
            if b in row:
                raise BlockPoolError(
                    f"block {b} already in table of {rid!r}")
        self._owned.setdefault(rid, [])
        for b in blocks:
            if self._ref[b] == 0:
                self._free.remove(b)                    # revive, content kept
                self._n_cached_free -= 1                # free+ref0 => cached
            self._ref[b] += 1
            self._owned[rid].append(b)

    def register(self, rid, block: int, key: bytes) -> bool:
        """Publish an owned block under a prefix hash so later requests can
        alias it. First writer wins: if `key` is already indexed (a
        concurrent identical prompt), this is a no-op and the caller's block
        stays private. Returns True iff the block was registered."""
        if rid not in self._owned or block not in self._owned[rid]:
            raise BlockPoolError(f"register: {rid!r} does not own {block}")
        if key in self._index:
            return False
        old = self._hash_of.get(block)
        if old is not None:
            if old == key:
                return False
            raise BlockPoolError(f"block {block} already registered")
        self._index[key] = block
        self._hash_of[block] = key
        self._m_registrations.inc()
        return True

    def match_prefix(self, keys: list) -> list:
        """Longest chain of cached blocks for the given chained prefix
        hashes: walks `keys` in order, stops at the first miss. Pure query —
        the scheduler updates `stats` only on the attempt that admits, so a
        blocked head request retried every step doesn't skew hit rates."""
        got = []
        for k in keys:
            b = self._index.get(k)
            if b is None:
                break
            got.append(b)
        return got

    def free_seq(self, rid) -> int:
        """Release every block of a sequence (refcount -1 each). Double-free
        raises. Blocks hitting refcount zero return to the free list: plain
        blocks at the hot end, prefix-cached blocks at the cold end so they
        survive longest (LRU eviction order). Released in reverse table
        order so a cached chain is evicted leaf-first — evicting the root
        first would make every still-cached descendant unmatchable (match
        walks the chain from the root)."""
        if rid not in self._owned:
            raise BlockPoolError(f"double free / unknown sequence {rid!r}")
        blocks = self._owned.pop(rid)
        for b in reversed(blocks):
            self._ref[b] -= 1
            if self._ref[b] == 0:
                if b in self._hash_of:
                    self._free.appendleft(b)            # evict-last, LRU
                    self._n_cached_free += 1
                else:
                    self._free.append(b)                # reuse-first
        return len(blocks)

    def drop_cache(self) -> int:
        """Clear the prefix index entirely. Cached-free blocks become plain
        free blocks — their content is forgotten, so they also move from the
        evict-last end of the free list to the reuse-first end (there is
        nothing left worth preserving; leaving garbage blocks parked behind
        never-used ones would starve reuse). Live registered blocks stay
        owned but are no longer shareable. Returns entries dropped."""
        n = len(self._index)
        plain, forgotten = [], []
        for b in self._free:
            if b in self._hash_of:
                self._m_evictions.inc()
                if self.on_evict is not None:
                    self.on_evict(b)
                forgotten.append(b)
            else:
                plain.append(b)
        self._free = deque(plain + forgotten)           # forgotten: reuse-first
        self._index.clear()
        self._hash_of.clear()
        self._n_cached_free = 0
        return n

    def defragment(self) -> np.ndarray:
        """Compact used blocks to the front of the pool.

        Returns `src` (num_blocks,) int32 such that the device pools must be
        permuted as ``new_pool[i] = old_pool[src[i]]``. Owner tables are
        rewritten in place to the new dense ids — a block shared by several
        tables moves ONCE and every owner follows. Cached-free blocks keep
        their content (they land right after the owned region) and the free
        list keeps its order, so reuse preference and LRU are preserved."""
        src = np.empty(self.num_blocks, np.int32)
        remap, nxt = {}, 0

        def place(old):
            nonlocal nxt
            if old not in remap:
                remap[old] = nxt
                src[nxt] = old
                nxt += 1
            return remap[old]

        for rid in self._owned:
            self._owned[rid] = [place(b) for b in self._owned[rid]]
        # cached-free blocks: content matters, keep them dense after the
        # owned region (in free-list order)
        for b in self._free:
            if b in self._hash_of:
                place(b)
        # plain free blocks: content is garbage, they fill the tail
        for b in self._free:
            if b not in self._hash_of:
                place(b)
        assert nxt == self.num_blocks
        self._free = deque(remap[b] for b in self._free)
        self._index = {k: remap[b] for k, b in self._index.items()}
        self._hash_of = {remap[b]: k for b, k in self._hash_of.items()}
        ref = [0] * self.num_blocks
        for old, new in remap.items():
            ref[new] = self._ref[old]
        self._ref = ref
        return src

    # ------------------------------------------------------------ checking
    def check(self) -> None:
        """Assert every pool invariant (used by the property-test harness
        after each step; cheap enough for test-time use)."""
        counts = [0] * self.num_blocks
        for rid, blocks in self._owned.items():
            assert len(set(blocks)) == len(blocks), \
                f"table of {rid!r} repeats a block"
            for b in blocks:
                counts[b] += 1
        for b in range(self.num_blocks):
            assert self._ref[b] == counts[b], \
                f"block {b}: refcount {self._ref[b]} != {counts[b]} owners"
        free = list(self._free)
        assert len(free) == len(set(free)), "free list repeats a block"
        for b in free:
            assert self._ref[b] == 0, f"block {b} free but referenced"
        assert len(free) + sum(1 for r in self._ref if r > 0) \
            == self.num_blocks, "free + owned != pool"
        for k, b in self._index.items():
            assert self._hash_of.get(b) == k, "index/hash_of out of sync"
        assert len(self._index) == len(self._hash_of), "index not a bijection"
        free_set = set(free)
        for b in self._hash_of:
            assert self._ref[b] > 0 or b in free_set, \
                f"registered block {b} neither owned nor free"
        scan = sum(1 for b in free if b in self._hash_of)
        assert self._n_cached_free == scan, \
            f"cached-free counter {self._n_cached_free} != scan {scan}"
