from repro.kernels.quantize import KVQuantConfig
from repro.serving.engine.engine import Engine, EngineConfig
from repro.serving.engine.oversub import OversubConfig, SLOPolicy
from repro.serving.engine.paged_cache import (BlockPool, BlockPoolError,
                                              prefix_hashes)
from repro.serving.engine.scheduler import Request, Scheduler
from repro.serving.engine.spec import (Drafter, DraftModelDrafter,
                                       NgramDrafter, ReplayDrafter,
                                       SpecConfig)
from repro.serving.telemetry import (MetricsRegistry, RecompileTracker,
                                     RequestTracer, Telemetry)

__all__ = ["Engine", "EngineConfig", "KVQuantConfig", "OversubConfig",
           "SLOPolicy",
           "BlockPool", "BlockPoolError", "Request", "Scheduler",
           "prefix_hashes", "MetricsRegistry", "RecompileTracker",
           "RequestTracer", "Telemetry", "SpecConfig", "Drafter",
           "NgramDrafter", "DraftModelDrafter", "ReplayDrafter"]
