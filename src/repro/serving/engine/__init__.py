from repro.serving.engine.engine import Engine, EngineConfig
from repro.serving.engine.paged_cache import (BlockPool, BlockPoolError,
                                              prefix_hashes)
from repro.serving.engine.scheduler import Request, Scheduler

__all__ = ["Engine", "EngineConfig", "BlockPool", "BlockPoolError",
           "Request", "Scheduler", "prefix_hashes"]
