"""Oversubscription policy for the continuous-batching engine: optimistic
admission, on-demand block growth, victim preemption, and SLO-aware
scheduling.

The conservative scheduler reserves blocks for a request's ENTIRE
``prompt + max_new`` span at admission — safe (an admitted request always
runs to completion) but wildly pessimistic at load: a request that declares
``max_new=256`` and stops after 12 tokens parks 15 unused blocks for its
whole lifetime, and the pool admits a fraction of the sequences it could
actually hold. The paper's concurrency analysis (§5-6) frames serving as
exactly this scheduling trade — device saturation vs. bounded per-sample
latency — and the optimistic/rollback taxonomy applies verbatim: allocate
lazily, detect conflict (an append that finds the pool full), roll a victim
back, recompute cheaply.

With ``EngineConfig.oversub = OversubConfig(...)`` the engine switches to:

  * **Optimistic admission** — reserve only ``block_cost(prompt + 1)``
    blocks (the prompt KV plus the first decode write); the generation
    budget is NOT reserved. A watermark gates admission so a slice of the
    pool stays free for decode growth: new sequences are admitted only
    while post-admission utilization stays at or under ``admit_watermark``
    (always admitting into an idle engine, so a single over-watermark
    request cannot deadlock).
  * **Per-step growth** — before each decode dispatch the engine appends
    the block(s) a sequence's next token needs (``BlockPool.append``), in
    the policy's protection order (strongest request first).
  * **Victim preemption** — when an append cannot be satisfied, the policy
    picks victims in preemption order; the engine registers every fully
    written block of ``prompt + generated`` in the prefix index FIRST (so
    the freed blocks park content-intact on the cold end of the free list),
    then evicts the victim's blocks and rolls it back to WAITING. Resume
    re-prefills ``prompt + generated`` through the ordinary cached-prefix
    admission path — on an all-full-attention config the recompute is
    usually one tail chunk.
  * **SLO-aware step shaping** — ``SLOPolicy`` chooses prefill-vs-decode
    per step from two signals: the head-of-queue wait against the TTFT
    target, and the recent per-step latency (a 1-token/step proxy for TPOT)
    against the TPOT target. Under TPOT pressure or above-watermark pool
    utilization the engine runs decode-only steps; a starving queue head
    (TTFT at risk) overrides and forces prefill through.

Ordering discipline (this is what makes preemption livelock-free): the
policy defines ONE total order over running requests — priority class
first, then invested work (generated tokens), then age — used forwards to
pick who grows first and backwards to pick who is evicted first. The
maximal request under this order is never chosen as a victim while anything
else is running, so it strictly advances and the system always makes
progress; within a class the least-invested victim loses the least
recompute. Requests preempted mid-flight keep their original arrival id as
the age tie-break, so resumed work is senior to newer traffic of the same
class.

Per-provider rollback protocol (``models.state_providers``): preemption is
evict-and-recompute, and every provider kind rolls back through the same
two hooks —

  * paged ``full`` KV: freed blocks ARE the rollback; fully written blocks
    are prefix-registered first so resume aliases them back.
  * ``ring`` KV: the write cursor is a pure function of the token count
    (``(p // bs) % R``), so ``preempt_checkpoint`` records just the resume
    length; re-prefilling ``prompt + generated`` rebuilds the ring,
    wrap-for-wrap, at the identical cursor.
  * recurrent slabs (``rwkv`` / ``mamba``): ``preempt_checkpoint`` snapshots
    the victim's slab rows to host; on resume the engine restores the
    snapshot (``resume_restore``) and — when EVERY provider restored
    state — skips the token re-scan entirely, resuming decode at the
    checkpointed length. Mixed (hybrid) configs recompute instead: the
    attention KV must be rebuilt anyway and the slab prefill scan rebuilds
    the recurrent state bit-identically from zero.

Everything here is host-side policy; device work stays in the Engine's
jitted step functions.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

__all__ = ["OversubConfig", "SLOPolicy"]


@dataclass(frozen=True)
class OversubConfig:
    """Knobs for optimistic admission + preemption. Frozen/hashable so it
    can ride inside ``EngineConfig`` (it is normalized out of the jit
    compile-cache key — pure host policy)."""

    admit_watermark: float = 0.90   # admit while post-admission pool
                                    #   utilization stays <= this fraction
    ttft_slo_s: float = 0.5         # target time-to-first-token; a queue
                                    #   head older than this forces prefill
    tpot_slo_s: float = 0.05        # target per-token latency; step EWMA
                                    #   above it defers prefill (decode-only)
    priority_preemption: bool = True  # a blocked higher-class queue head may
                                    #   evict strictly-lower-class victims
    snapshot_resume: bool = True    # pure-recurrent configs restore slab
                                    #   snapshots instead of re-prefilling
    step_ewma: float = 0.2          # weight of the newest step duration in
                                    #   the TPOT-proxy moving average

    def __post_init__(self):
        if not 0.0 < self.admit_watermark <= 1.0:
            raise ValueError(
                f"admit_watermark {self.admit_watermark} outside (0, 1]")
        if not 0.0 < self.step_ewma <= 1.0:
            raise ValueError(f"step_ewma {self.step_ewma} outside (0, 1]")


class SLOPolicy:
    """Scheduling decisions under oversubscription. Pure host state: a
    step-duration EWMA (the TPOT proxy — the engine emits at most one token
    per slot per step, so per-step wall time bounds per-token latency) and
    the ordering/gating rules. Deterministic given its inputs, so tests can
    drive it with a fake clock."""

    def __init__(self, cfg: OversubConfig,
                 clock: Callable[[], float] = time.perf_counter):
        self.cfg = cfg
        self.clock = clock
        self.step_ewma_s: Optional[float] = None    # None until first step

    # ------------------------------------------------------------ ordering
    @staticmethod
    def protection_key(req):
        """Total order, strongest first: highest priority class (lowest
        number), then most generated tokens (most work to lose), then
        oldest arrival. Growth is granted in this order and the head of it
        is never victimized while anything weaker runs — the progress
        guarantee."""
        return (req.priority, -len(req.out_tokens), req.rid)

    @classmethod
    def victim_order(cls, reqs) -> list:
        """Weakest first — the exact reverse of ``protection_key``: lowest
        class, then least invested (cheapest recompute), then youngest."""
        return sorted(reqs, key=cls.protection_key, reverse=True)

    def pick_victim(self, candidates, *, max_priority: Optional[int] = None):
        """The next request to evict, or None. ``max_priority`` restricts
        victims to classes STRICTLY weaker (larger number) than it — the
        priority-preemption rule for a blocked queue head."""
        pool = [r for r in candidates
                if max_priority is None or r.priority > max_priority]
        order = self.victim_order(pool)
        return order[0] if order else None

    # ----------------------------------------------------------- admission
    def may_admit(self, pool, fresh_blocks: int, revived_blocks: int,
                  running: int) -> bool:
        """Watermark-gated optimistic admission: the reservation itself must
        fit AND post-admission utilization must stay at or under the
        watermark, keeping headroom for decode growth. An idle engine
        always admits (a request whose prompt alone exceeds the watermark
        must still be servable — it fits the pool, validated at submit)."""
        if fresh_blocks + revived_blocks > pool.num_free:
            return False
        if running == 0:
            return True
        used_after = (pool.num_blocks - pool.num_free) \
            + fresh_blocks + revived_blocks
        return used_after <= self.cfg.admit_watermark * pool.num_blocks

    # --------------------------------------------------------- step shaping
    def note_step(self, dt_s: float) -> None:
        """Feed one engine-step wall duration into the TPOT-proxy EWMA."""
        if self.step_ewma_s is None:
            self.step_ewma_s = dt_s
        else:
            a = self.cfg.step_ewma
            self.step_ewma_s = a * dt_s + (1.0 - a) * self.step_ewma_s

    def allow_prefill(self, *, head_wait_s: Optional[float],
                      decoding: int, pool_util: float) -> bool:
        """Prefill-vs-decode for this step. Prefill is deferred when the
        decode side is under pressure — pool above the admission watermark
        (appends are about to evict) or the step EWMA above the TPOT
        target — EXCEPT when nothing is decoding (deferring would deadlock)
        or the queue head has waited past the TTFT target (p99 TTFT is the
        SLO prefill protects)."""
        if decoding == 0:
            return True
        if head_wait_s is not None and head_wait_s >= self.cfg.ttft_slo_s:
            return True
        if pool_util > self.cfg.admit_watermark:
            return False
        if (self.step_ewma_s is not None
                and self.step_ewma_s > self.cfg.tpot_slo_s):
            return False
        return True
