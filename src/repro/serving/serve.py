"""Batched serving: prefill + token-by-token decode with KV/recurrent cache.

`make_serve_step` builds the jitted one-token step used by the decode dry-run
shapes (decode_32k, long_500k): ONE new token against a cache of seq_len.
`generate` drives a full sampling loop (used by examples/serve_demo.py) and
is the bit-exactness oracle for the continuous-batching engine across ALL
families (full / sliding / ssm / hybrid — per-layer state providers).
`engine_generate` routes the same request shape through the Engine in one
call for demos, benchmarks, and equality tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import parallelism as par
from repro.models import transformer as T


def make_serve_step(cfg, plan=None):
    """serve_step(params, cache, inputs, index) -> (logits (B,V), new cache)."""

    def serve_step(params, cache, inputs, index):
        ctx = par.plan_context(plan) if plan is not None else _null()
        with ctx:
            return T.decode_step(cfg, params, cache, inputs, index)

    return serve_step


def jit_serve_step(cfg, plan, params_abs, cache_abs, inputs_abs):
    step = make_serve_step(cfg, plan)
    p_sh = plan.param_shardings(params_abs)
    c_sh = plan.cache_shardings(cache_abs)
    i_sh = jax.tree.map(
        lambda l: NamedSharding(plan.mesh, plan.spec_for_batch_leaf("token", l.shape)),
        inputs_abs)
    rep = NamedSharding(plan.mesh, P())
    return jax.jit(
        step,
        in_shardings=(p_sh, c_sh, i_sh, rep),
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
    )


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


# jitted-step caches keyed by the (hashable, frozen) ModelConfig so repeated
# generate() calls don't re-trace
@functools.lru_cache(maxsize=None)
def _cached_decode_step(cfg):
    return jax.jit(lambda p, c, tok, i: T.decode_step(cfg, p, c, {"token": tok}, i))


@functools.lru_cache(maxsize=None)
def _cached_prefill_step(cfg):
    return jax.jit(lambda p, c, toks: T.prefill_step(cfg, p, c, {"tokens": toks}))


def sample(logits, key, temperature=1.0):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


def generate(cfg, params, prompt_tokens, max_new, *, key=None, temperature=0.0,
             max_len=None, prefill_mode="auto", kv_quant=None):
    """Greedy/temperature generation for token-input models.

    Prefill fills the whole prompt cache in ONE jitted call (`prefill_step`)
    instead of S0 sequential decode steps; `prefill_mode="loop"` keeps the
    old token-by-token path as a reference oracle ("auto" falls back to it
    for recurrent families without a batched prefill). ``kv_quant`` stores
    the dense KV caches int8 + per-vector scales — the non-paged reference
    the quantized engine must match token-for-token."""
    key = key if key is not None else jax.random.PRNGKey(0)
    B, S0 = prompt_tokens.shape
    max_len = max_len or (S0 + max_new)
    cache = T.init_decode_state(cfg, B, max_len, kv_quant=kv_quant)
    step = _cached_decode_step(cfg)

    if prefill_mode not in ("auto", "batched", "loop"):
        raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
    if prefill_mode == "auto":
        prefill_mode = "batched" if T.supports_batched_prefill(cfg) else "loop"
    # labeled spans so device traces separate the prefill and decode phases
    # (the engine labels its phases the same way — serving.telemetry)
    if prefill_mode == "batched":
        with jax.profiler.TraceAnnotation("serve/prefill"):
            logits, cache = _cached_prefill_step(cfg)(params, cache,
                                                      prompt_tokens)
    else:  # reference path: token-by-token (any family)
        logits = None
        with jax.profiler.TraceAnnotation("serve/prefill"):
            for i in range(S0):
                logits, cache = step(params, cache, prompt_tokens[:, i],
                                     jnp.int32(i))
    out = []
    with jax.profiler.TraceAnnotation("serve/decode"):
        for j in range(max_new):
            key, sub = jax.random.split(key)
            tok = sample(logits, sub, temperature)
            out.append(tok)
            logits, cache = step(params, cache, tok, jnp.int32(S0 + j))
    return jnp.stack(out, axis=1)


def engine_generate(cfg, params, prompts, max_news, *, engine_cfg=None,
                    plan=None, return_engine=False):
    """Greedy generation for a batch of VARIABLE-length prompts through the
    continuous-batching Engine (any family the state providers cover: full,
    sliding, ssm, hybrid). `prompts`: list of 1-D int token arrays;
    `max_news`: per-request generation budgets. Returns a list of np arrays
    in request order — greedy outputs are bit-identical to per-request
    `generate` calls. With `return_engine=True` also returns the drained
    Engine so callers can read `engine.telemetry` (request timelines, metric
    snapshots, exporters)."""
    from repro.serving.engine import Engine, EngineConfig
    eng = Engine(cfg, params, engine_cfg or EngineConfig(), plan=plan)
    rids = [eng.add_request(p, int(m)) for p, m in zip(prompts, max_news)]
    outs = eng.drain()
    outs = [outs[r] for r in rids]
    return (outs, eng) if return_engine else outs
