"""Serving telemetry: metrics registry, request-lifecycle tracing, recompile
tracking, and exporters for the continuous-batching engine.

The source paper's concurrency analysis (§6-7) is measurement-driven —
operator/batch/pipeline trade-offs only become visible with per-phase timing
and utilization — and the engine's next scaling steps (AOT-bucketed prefill,
SLO-aware scheduling) need signals throughput alone cannot provide:
time-to-first-token, queue-wait distributions, and serving-time
recompilation events. This module is the one place those signals live; the
engine, scheduler, and block pool publish into it instead of keeping ad-hoc
``stats`` dicts.

Four pieces, composable but independently usable:

  * ``MetricsRegistry`` — named ``Counter`` / ``Gauge`` / ``Histogram``
    metrics. Histograms answer arbitrary quantiles from a bounded-memory
    streaming sketch (exact until the buffer first compacts, rank error
    ~1/cap after).
  * ``RequestTracer`` — append-only event log of per-request lifecycle
    events (``arrive``/``admit``/``prefix_hit``/``prefill_chunk``/
    ``first_token``/``decode_token``/``evict``/``defrag``/``finish``) with
    monotonic ``time.perf_counter`` timestamps, so TTFT, queue wait, and
    per-phase latency are *derived* (``derive_timeline``) rather than
    guessed.
  * ``RecompileTracker`` — wraps jitted step functions and counts unique
    (function, arg shapes/dtypes) trace keys: the number of distinct
    compiled step variants a serving run dispatched, the precursor metric
    for AOT-compiled prefill buckets.
  * Exporters — ``export_jsonl`` (one JSON object per event; replayable via
    ``replay_jsonl`` into per-request timelines) and ``prometheus_text``
    (Prometheus text-format snapshot; histograms as summaries).

``Telemetry`` bundles the four behind one ``enabled`` switch
(``EngineConfig.telemetry``): when disabled every record call is a cheap
early return, no events are stored, and engine outputs are unchanged —
telemetry never touches device code, only host bookkeeping around it.

Metric naming scheme (see the engine README's Telemetry section):
``<subsystem>_<quantity>_<unit>`` with ``_total`` for counters and
``_seconds`` for duration histograms, e.g. ``engine_decode_steps_total``,
``engine_request_ttft_seconds``, ``pool_evictions_total``.
"""
from __future__ import annotations

import json
import math
import time
from typing import Callable, NamedTuple, Optional

import jax
import numpy as np

# Canonical request-lifecycle event names, in lifecycle order. ``evict`` and
# ``defrag`` are pool-wide events recorded with ``rid=None``. ``preempt`` /
# ``resume`` bracket an oversubscription rollback: the victim's state is
# evicted and it re-enters the prefill phase on resume, so the rank machine
# in ``validate_order`` resets at each ``resume``. ``verify`` is the
# speculative-decoding acceptance record (drafted/accepted counts); it ranks
# WITH ``decode_token`` — each verify step emits both, in either order.
EVENTS = ("arrive", "admit", "prefix_hit", "prefill_chunk", "first_token",
          "verify", "decode_token", "preempt", "resume", "evict", "defrag",
          "finish")

_LIFECYCLE_RANK = {"arrive": 0, "admit": 1, "resume": 1, "prefix_hit": 2,
                   "prefill_chunk": 3, "first_token": 4, "verify": 5,
                   "decode_token": 5, "preempt": 6, "finish": 7}
_ONCE = ("arrive", "admit", "first_token", "finish")


class TelemetryError(ValueError):
    """Metric registration conflict or event-stream invariant violation."""


# ---------------------------------------------------------------- metrics
class Counter:
    """Monotonically non-decreasing value (int or float increments)."""
    kind = "counter"
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0

    def inc(self, n=1) -> None:
        if n < 0:
            raise TelemetryError(f"counter {self.name!r}: negative inc {n}")
        self.value += n


class Gauge:
    """Instantaneous value: ``set`` to a level or ``add`` a delta."""
    kind = "gauge"
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def add(self, d) -> None:
        self.value += d


class Histogram:
    """Streaming-quantile histogram with bounded memory.

    Weighted samples accumulate in a buffer; when it reaches ``2*cap`` it is
    sorted and adjacent pairs merge (weighted-mean value, summed weight),
    halving it back to ``cap``. Until the first compaction, ``quantile`` is
    EXACT — identical to ``np.percentile(data, q)`` (linear interpolation) —
    and afterwards the rank error is bounded by the largest merged weight
    over the total count (~1/cap per compaction generation).
    ``count``/``sum``/``min``/``max`` are exact always.
    """
    kind = "histogram"

    def __init__(self, name: str, help: str = "", cap: int = 4096):
        if cap < 2:
            raise TelemetryError(f"histogram {name!r}: cap must be >= 2")
        self.name, self.help, self.cap = name, help, int(cap)
        self._v: list = []
        self._w: list = []
        self._dirty = False
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, x) -> None:
        x = float(x)
        self._v.append(x)
        self._w.append(1.0)
        self._dirty = True
        self.count += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        if len(self._v) >= 2 * self.cap:
            self._compact()

    def _sort(self):
        v, w = np.asarray(self._v), np.asarray(self._w)
        if self._dirty:
            o = np.argsort(v, kind="stable")
            v, w = v[o], w[o]
            self._v, self._w = v.tolist(), w.tolist()
            self._dirty = False
        return v, w

    def _compact(self) -> None:
        v, w = self._sort()
        tail = len(v) % 2
        if tail:                        # odd buffer: largest sample rides along
            v_last, w_last = float(v[-1]), float(w[-1])
            v, w = v[:-1], w[:-1]
        wp = w[0::2] + w[1::2]
        vp = (v[0::2] * w[0::2] + v[1::2] * w[1::2]) / wp
        self._v, self._w = vp.tolist(), wp.tolist()
        if tail:
            self._v.append(v_last)
            self._w.append(w_last)

    def quantile(self, q) -> float:
        """The q-th percentile (q in [0, 100]) of everything observed."""
        if not 0 <= q <= 100:
            raise TelemetryError(f"quantile {q} outside [0, 100]")
        if self.count == 0:
            return math.nan
        v, w = self._sort()
        if len(v) == 1:
            return float(v[0])
        # sample i sits at rank position C_{i-1} + (w_i - 1)/2; with unit
        # weights that is exactly i, so np.interp below reproduces
        # np.percentile's linear interpolation bit for bit.
        c = np.cumsum(w)
        pos = c - 1.0 - (w - 1.0) / 2.0
        t = (c[-1] - 1.0) * (q / 100.0)
        return float(np.interp(t, pos, v))

    def quantiles(self, qs=(50, 99)) -> dict:
        return {q: self.quantile(q) for q in qs}


class MetricsRegistry:
    """Get-or-create registry of named metrics; one per serving stack so the
    engine, scheduler, and block pool export through a single snapshot."""

    def __init__(self):
        self._metrics: dict = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, **kw)
        elif not isinstance(m, cls):
            raise TelemetryError(
                f"metric {name!r} already registered as {m.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "", cap: int = 4096) -> Histogram:
        return self._get(Histogram, name, help, cap=cap)

    def get(self, name: str):
        return self._metrics[name]

    def names(self) -> list:
        return list(self._metrics)

    def snapshot(self) -> dict:
        """Plain-python view: scalars for counters/gauges, summary dicts for
        histograms."""
        out = {}
        for name, m in self._metrics.items():
            if m.kind == "histogram":
                out[name] = {"count": m.count, "sum": m.sum,
                             "min": m.min, "max": m.max,
                             "p50": m.quantile(50), "p99": m.quantile(99)}
            else:
                out[name] = m.value
        return out

    def prometheus_text(self) -> str:
        """Prometheus text-format snapshot. Histograms are exported as
        summaries (quantile-labelled samples + ``_sum``/``_count``)."""
        lines = []
        for name, m in self._metrics.items():
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            if m.kind == "histogram":
                lines.append(f"# TYPE {name} summary")
                if m.count:
                    for q in (0.5, 0.9, 0.99):
                        lines.append(
                            f'{name}{{quantile="{q}"}} {m.quantile(q * 100)}')
                lines.append(f"{name}_sum {m.sum}")
                lines.append(f"{name}_count {m.count}")
            else:
                lines.append(f"# TYPE {name} {m.kind}")
                lines.append(f"{name} {m.value}")
        return "\n".join(lines) + "\n"


# ----------------------------------------------------------------- tracing
class Event(NamedTuple):
    t: float                    # monotonic seconds (time.perf_counter)
    rid: Optional[int]          # None for pool-wide events (evict/defrag)
    name: str
    data: Optional[dict]


class RequestTracer:
    """Append-only lifecycle event log, indexed globally and per request."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self.events: list = []
        self._by_rid: dict = {}

    def record(self, rid, name: str, **data) -> float:
        t = self.clock()
        ev = Event(t, rid, name, data or None)
        self.events.append(ev)
        if rid is not None:
            self._by_rid.setdefault(rid, []).append(ev)
        return t

    def request_events(self, rid) -> list:
        return list(self._by_rid.get(rid, ()))

    def request_ids(self) -> list:
        return list(self._by_rid)

    def first(self, rid, name: str) -> Optional[float]:
        for ev in self._by_rid.get(rid, ()):
            if ev.name == name:
                return ev.t
        return None


def derive_timeline(events) -> dict:
    """Fold one request's event stream into its derived timeline: TTFT =
    ``first_token - arrive``, queue wait = ``admit - arrive``, end-to-end =
    ``finish - arrive``, the per-token decode timeline, and the preemption
    view — ``preempts`` (rollback count) and ``preempted_s`` (total time
    spent evicted, summed over matched preempt→resume pairs; a stream that
    ends while still evicted contributes its open interval up to the last
    event's timestamp). Speculative decoding: a ``decode_token`` event may
    carry ``tokens=n`` (the accepted run of one verify step) — the decode
    timeline counts every ACCEPTED token, n entries at that timestamp, so
    TPOT statistics stay per-token rather than per-engine-step; drafted /
    accepted totals are summed from the ``verify`` events."""
    tl = {"events": list(events), "arrive": None, "admit": None,
          "first_token": None, "finish": None, "prefill_chunks": 0,
          "decode_tokens": [], "prefix_hit_tokens": 0,
          "preempts": 0, "preempted_s": 0.0,
          "draft_tokens": 0, "accepted_tokens": 0}
    pend = None                        # open preempt awaiting its resume
    for ev in events:
        if ev.name in _ONCE and tl[ev.name] is None:
            tl[ev.name] = ev.t
        elif ev.name == "prefill_chunk":
            tl["prefill_chunks"] += 1
        elif ev.name == "decode_token":
            tl["decode_tokens"].extend(
                [ev.t] * (ev.data or {}).get("tokens", 1))
        elif ev.name == "verify":
            tl["draft_tokens"] += (ev.data or {}).get("drafted", 0)
            tl["accepted_tokens"] += (ev.data or {}).get("accepted", 0)
        elif ev.name == "prefix_hit":
            # cumulative over resumes: a rollback's re-admission usually
            # re-aliases the blocks registered at preemption
            tl["prefix_hit_tokens"] += (ev.data or {}).get("tokens", 0)
        elif ev.name == "preempt":
            tl["preempts"] += 1
            pend = ev.t
        elif ev.name == "resume":
            if pend is not None:
                tl["preempted_s"] += ev.t - pend
                pend = None
    if pend is not None and events:
        tl["preempted_s"] += events[-1].t - pend
    for key, a, b in (("queue_wait", "arrive", "admit"),
                      ("ttft", "arrive", "first_token"),
                      ("e2e", "arrive", "finish")):
        tl[key] = (tl[b] - tl[a]
                   if tl[a] is not None and tl[b] is not None else None)
    return tl


def validate_order(events) -> None:
    """Assert one request's lifecycle invariants: timestamps never regress,
    arrive ≤ admit ≤ (prefix_hit | prefill_chunk)* ≤ first_token ≤
    decode_token* ≤ finish, and the one-shot events occur at most once.

    Preemption segments the stream: ``preempt`` is legal any time after
    ``admit``, nothing but ``resume`` may follow it (the request is evicted
    — though a stream may END evicted), and ``resume`` resets the rank
    floor so the request re-runs prefix_hit / prefill_chunk / decode_token
    phases; ``resume`` without an open ``preempt`` is an error. One-shot
    events stay globally one-shot across segments (``first_token`` fires in
    whichever segment first completes prefill). Raises ``TelemetryError``
    with the offending pair."""
    if not events:
        raise TelemetryError("empty event stream")
    names = [e.name for e in events]
    for n in _ONCE:
        if names.count(n) > 1:
            raise TelemetryError(f"duplicate {n!r} event")
    if names[0] != "arrive":
        raise TelemetryError(f"stream starts with {names[0]!r}, not 'arrive'")
    if "finish" in names and names[-1] != "finish":
        raise TelemetryError("events recorded after 'finish'")
    floor = _LIFECYCLE_RANK["arrive"]
    evicted = False
    prev = events[0]
    for ev in events[1:]:
        if ev.t < prev.t:
            raise TelemetryError(
                f"timestamp regression: {prev.name}@{prev.t} -> "
                f"{ev.name}@{ev.t}")
        rank = _LIFECYCLE_RANK.get(ev.name)
        if rank is None:
            raise TelemetryError(f"unknown lifecycle event {ev.name!r}")
        if evicted:
            if ev.name != "resume":
                raise TelemetryError(
                    f"{ev.name!r} recorded while evicted (preempt without "
                    f"resume)")
            evicted = False
            floor = rank                       # segment restart: rank resets
        elif ev.name == "resume":
            raise TelemetryError("'resume' without a preceding 'preempt'")
        elif ev.name == "preempt":
            if floor < _LIFECYCLE_RANK["admit"]:
                raise TelemetryError("'preempt' before 'admit'")
            evicted = True
            floor = rank
        else:
            if rank < floor:
                raise TelemetryError(
                    f"lifecycle order violated: {prev.name!r} before "
                    f"{ev.name!r}")
            floor = rank
        prev = ev


# -------------------------------------------------------- recompile tracking
def abstract_signature(args) -> tuple:
    """Hashable trace key of a jitted call's arguments: pytree structure +
    per-leaf (shape, dtype). Two calls share a compiled executable iff their
    keys match (for fixed static config), so counting unique keys counts
    distinct compiled variants."""
    leaves, treedef = jax.tree_util.tree_flatten(args)
    return treedef, tuple(
        (np.shape(l), np.result_type(l).name) for l in leaves)


class RecompileTracker:
    """Wrap jitted functions; count unique (function, trace-key) pairs.

    The count is the number of distinct compiled step variants this serving
    run dispatched — the metric AOT-compiled prefill buckets must hold at
    "known set, counted up front, zero at serving time".
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.seen: dict = {}            # fn name -> set of trace keys
        reg = registry if registry is not None else MetricsRegistry()
        self._counter = reg.counter(
            "engine_compiled_variants_total",
            "distinct (step fn, arg shapes/dtypes) trace keys dispatched")

    def wrap(self, name: str, fn):
        seen = self.seen.setdefault(name, set())
        counter = self._counter

        def tracked(*args):
            key = abstract_signature(args)
            if key not in seen:
                seen.add(key)
                counter.inc()
            return fn(*args)

        tracked.__name__ = f"tracked_{name}"
        tracked.__wrapped__ = fn
        return tracked

    def unique(self, name: str) -> int:
        return len(self.seen.get(name, ()))

    def variants(self) -> dict:
        return {name: len(keys) for name, keys in self.seen.items()}

    @property
    def total(self) -> int:
        return sum(len(keys) for keys in self.seen.values())


# ----------------------------------------------------------------- bundle
class _NullSpan:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


_NULL_SPAN = _NullSpan()


class Telemetry:
    """One serving stack's telemetry: registry + tracer + recompile tracker
    + engine-step timeline, behind a single ``enabled`` switch.

    ``step_timing`` additionally blocks on device results inside the engine's
    timed path so each step's host/device split is real compute time, not
    async dispatch (mirrors serving_bench's latency pass); it is off by
    default because blocking serializes the host-ahead pipeline.
    """

    def __init__(self, enabled: bool = True, step_timing: bool = False,
                 registry: Optional[MetricsRegistry] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.enabled = bool(enabled)
        self.step_timing = bool(step_timing) and self.enabled
        self.clock = clock
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = RequestTracer(clock=clock)
        self.recompiles = RecompileTracker(self.registry)
        self.steps: list = []           # per-step dicts (step_timing only)
        self._h_host = self.registry.histogram(
            "engine_step_host_seconds",
            "per-step host scheduling time (step_timing runs only)")
        self._h_dev = self.registry.histogram(
            "engine_step_device_seconds",
            "per-step blocked device time (step_timing runs only)")

    # -- recording (no-ops when disabled) --------------------------------
    def record(self, rid, event: str, **data) -> Optional[float]:
        if not self.enabled:
            return None
        return self.tracer.record(rid, event, **data)

    def span(self, name: str):
        """`jax.profiler.TraceAnnotation` span so device traces are labeled
        per phase; a no-op context manager when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return jax.profiler.TraceAnnotation(name)

    def record_step(self, *, host_s: float, device_s: float, **data) -> None:
        if not self.step_timing:
            return
        self._h_host.observe(host_s)
        self._h_dev.observe(device_s)
        self.steps.append({"step": len(self.steps), "host_s": host_s,
                           "device_s": device_s, **data})

    # -- views -----------------------------------------------------------
    def request_timeline(self, rid) -> dict:
        return derive_timeline(self.tracer.request_events(rid))

    # -- exporters -------------------------------------------------------
    def export_jsonl(self, path) -> int:
        """Write the event log as JSON Lines (one event per line). Returns
        the number of events written. ``replay_jsonl`` parses it back into
        per-request timelines."""
        with open(path, "w") as f:
            for ev in self.tracer.events:
                row = {"t": ev.t, "rid": ev.rid, "event": ev.name}
                if ev.data:
                    row["data"] = ev.data
                f.write(json.dumps(row) + "\n")
        return len(self.tracer.events)

    def prometheus_text(self) -> str:
        return self.registry.prometheus_text()


def replay_jsonl(path) -> dict:
    """Parse a JSONL trace back into ``{rid: derived timeline}`` — the same
    TTFT / queue-wait / decode-timeline view a live ``Telemetry`` computes,
    so traces from a bench run can be analyzed offline."""
    by_rid: dict = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            rid = row.get("rid")
            if rid is None:
                continue
            by_rid.setdefault(rid, []).append(
                Event(row["t"], rid, row["event"], row.get("data")))
    return {rid: derive_timeline(sorted(evs, key=lambda e: e.t))
            for rid, evs in by_rid.items()}
