"""Synthetic serving workloads: closed-loop request mixes and an open-loop
arrival process for overload studies.

The closed-loop generators (`mixed_workload`, `shared_prefix_workload`) are
the standing benchmark traffic shapes: chat-shaped mixed lengths, and a
common system-prompt prefix with unique suffixes (the prefix-cache sweet
spot). They hand the driver a complete request list to submit up front —
throughput under a drained backlog.

`open_loop_arrivals` models the regime the paper's tail-latency analysis
warns about (Keuper & Pfreundt: under oversubscription it is the p99, not
the mean, that collapses): requests arrive by a Poisson process the server
cannot push back on, prompt and output lengths are heavy-tailed
(lognormal), and a small fraction of traffic is higher priority. Arrival
times are in ENGINE-STEP units: the engine emits at most one token per slot
per step and the decode step cost is constant (fixed shapes + masking), so
offered load in tokens/step against a capacity of ``max_slots`` tokens/step
defines the overload factor directly — ``rate * mean(max_new) =
overload * max_slots``. The driver admits every arrival whose step has
come, steps the engine, and repeats; the queue is open-loop because
arrivals never wait for completions.

All generators are seeded and pure: one rng per call, no global state, so
a (seed, params) pair reproduces the byte-identical trace — the
oversubscription benchmark replays ONE trace through both the optimistic
and the full-reservation engines.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["Arrival", "mixed_workload", "shared_prefix_workload",
           "spec_workload", "open_loop_arrivals"]


def mixed_workload(n: int = 24, seed: int = 0, vocab: int = 256):
    """Chat-shaped mixed lengths: short prompts (4-31 tokens), skewed
    generation budgets (70% short 8-23, 30% long 48-95). Returns
    (prompts, max_news)."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(4, 32, size=n)
    news = np.where(rng.random(n) < 0.3, rng.integers(48, 96, size=n),
                    rng.integers(8, 24, size=n))
    prompts = [rng.integers(0, vocab, size=int(l)).astype(np.int32)
               for l in lens]
    return prompts, [int(m) for m in news]


def shared_prefix_workload(n: int = 24, seed: int = 0, prefix_len: int = 96,
                           vocab: int = 256):
    """Shared-prefix traffic: one common system prompt + short unique
    suffixes, short generations (prefill-dominated — the prefix-cache
    sweet spot). Returns (prompts, max_news, prefix)."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, size=prefix_len).astype(np.int32)
    prompts, news = [], []
    for _ in range(n):
        tail = rng.integers(0, vocab,
                            size=int(rng.integers(4, 17))).astype(np.int32)
        prompts.append(np.concatenate([prefix, tail]))
        news.append(int(rng.integers(8, 17)))
    return prompts, news, prefix


def spec_workload(n: int = 8, seed: int = 0, vocab: int = 256):
    """Speculation-friendly decode-heavy traffic: short prompts built from
    small repeating token patterns (period 2-4) and long generation budgets,
    so the run is dominated by decode steps and the n-gram drafter's
    prompt-lookahead has literal earlier occurrences to extend. Returns
    (prompts, max_news)."""
    rng = np.random.default_rng(seed)
    prompts, news = [], []
    for _ in range(n):
        period = int(rng.integers(2, 5))
        pat = rng.integers(0, vocab, size=period).astype(np.int32)
        length = int(rng.integers(8, 17))
        prompts.append(np.tile(pat, length // period + 1)[:length])
        news.append(int(rng.integers(48, 97)))
    return prompts, news


@dataclass(frozen=True)
class Arrival:
    """One open-loop request: submit `prompt` for `max_new` tokens at
    priority `priority` once the engine reaches step `step`."""
    step: int
    prompt: np.ndarray
    max_new: int
    priority: int


def _lognormal_len(rng, mean: float, lo: int, hi: int, sigma: float) -> int:
    """Heavy-tailed length with the requested mean: lognormal keeps a long
    right tail (the occasional huge request that ties resources up) while
    most draws sit well below the mean."""
    mu = np.log(mean) - 0.5 * sigma * sigma   # E[lognormal(mu, s)] = mean
    return int(np.clip(round(rng.lognormal(mu, sigma)), lo, hi))


def open_loop_arrivals(n: int, *, seed: int = 0, overload: float = 2.0,
                       max_slots: int = 8, prompt_mean: float = 12.0,
                       prompt_max: int = 32, out_mean: float = 24.0,
                       out_max: int = 96, sigma: float = 0.7,
                       hi_priority_frac: float = 0.2,
                       vocab: int = 256) -> list:
    """Poisson arrivals at `overload` times the engine's decode capacity.

    The arrival rate in requests/step is ``overload * max_slots /
    out_mean`` — each request will eventually demand ~`out_mean` decode
    tokens and the engine can emit at most `max_slots` tokens/step, so
    `overload` > 1 means the offered token load exceeds what decode can
    drain and a backlog must form. Prompt/output lengths are lognormal
    (heavy-tailed) with the given means; `hi_priority_frac` of requests are
    class 0 (interactive), the rest class 1 (batch). Returns Arrivals
    sorted by step."""
    if overload <= 0:
        raise ValueError(f"overload must be positive, got {overload}")
    rng = np.random.default_rng(seed)
    rate = overload * max_slots / out_mean          # requests per step
    t = 0.0
    out = []
    for _ in range(n):
        t += rng.exponential(1.0 / rate)
        prompt_len = _lognormal_len(rng, prompt_mean, 1, prompt_max, sigma)
        out.append(Arrival(
            step=int(t),
            prompt=rng.integers(0, vocab, size=prompt_len).astype(np.int32),
            max_new=_lognormal_len(rng, out_mean, 1, out_max, sigma),
            priority=0 if rng.random() < hi_priority_frac else 1))
    return out
