import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable (e)) + roofline extraction (deliverable (g)).

For a given (architecture × input shape × mesh × plan):
  1. build the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. lower + compile train_step (train/prefill shapes) or serve_step
     (decode shapes) against ShapeDtypeStruct inputs — no allocation,
  3. print memory_analysis() (fits?) and cost_analysis() (FLOPs/bytes),
  4. parse collective bytes from the optimized HLO,
  5. emit roofline terms + MODEL_FLOPS ratio as JSON.

Run one combination per process (the 512 fake devices are locked in at jax
init):  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b \
            --shape train_4k --mesh single --plan dp_tp
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, get_config
from repro.core import costmodel as cm
from repro.core import hlo_analysis as ha
from repro.core import parallelism as par
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, shape_applicable
from repro.models import transformer as T
from repro.optim import make_optimizer
from repro.train import trainer
from repro.serving import serve


def lower_combo(cfg, shape, mesh, plan_name, cfg_overrides=None, accum_steps=1):
    import dataclasses
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    plan = par.make_plan(plan_name, mesh)
    specs = input_specs(cfg, shape)
    optimizer = make_optimizer("adam", lr=1e-4)

    if shape.kind in ("train", "prefill"):
        state_abs = trainer.abstract_state(cfg, optimizer)
        if shape.kind == "train":
            step = trainer.make_train_step(cfg, optimizer, plan,
                                           accum_steps=accum_steps)
            st_sh = trainer.state_shardings(state_abs, plan)
            b_sh = plan.batch_shardings(specs["batch"])
            rep = NamedSharding(plan.mesh, P())
            jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, {"loss": rep}),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_abs, specs["batch"])
        else:
            # prefill: forward pass producing last-position logits
            def prefill(params, batch):
                with par.plan_context(plan):
                    hidden, _ = T.forward(cfg, params, batch)
                return T.logits(cfg, params, hidden[:, -1:, :])

            p_sh = plan.param_shardings(state_abs["params"])
            b_sh = plan.batch_shardings(specs["batch"])
            jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(state_abs["params"], specs["batch"])
        tokens = shape.global_batch * shape.seq_len
    else:
        params_abs = jax.eval_shape(
            lambda k: T.init_params(cfg, k), jax.random.PRNGKey(0))
        step = serve.make_serve_step(cfg, plan)
        p_sh = plan.param_shardings(params_abs)
        c_sh = plan.cache_shardings(specs["cache"])
        i_sh = jax.tree.map(
            lambda l: NamedSharding(mesh, plan.spec_for_batch_leaf("token", l.shape)),
            specs["inputs"])
        rep = NamedSharding(mesh, P())
        jitted = jax.jit(step, in_shardings=(p_sh, c_sh, i_sh, rep),
                         out_shardings=(None, c_sh), donate_argnums=(1,))
        lowered = jitted.lower(params_abs, specs["cache"], specs["inputs"],
                               jax.ShapeDtypeStruct((), jnp.int32))
        tokens = shape.global_batch  # ONE new token per sequence

    return lowered, tokens


def run(arch, shape_name, mesh_kind, plan_name, out_path=None, quiet=False,
        accum_steps=1):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "plan": plan_name, "accum_steps": accum_steps,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        _emit(rec, out_path, quiet)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    lowered, tokens = lower_combo(cfg, shape, mesh, plan_name,
                                  accum_steps=accum_steps)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    analysis = ha.analyze_compiled(lowered, compiled)
    mem = analysis["memory"]
    if not quiet:
        print("memory_analysis:", json.dumps(mem, indent=1))
        print("cost_analysis (xla, loop-unaware): flops=%.3e bytes=%.3e"
              % (analysis["xla_cost_flops"], analysis["xla_cost_bytes"]))
        print("loop-aware: flops=%.3e hbm=%.3e coll=%.3e"
              % (analysis["flops"], analysis["hbm_bytes"],
                 analysis["collectives"]["total"]))

    # parsed quantities are per-device (SPMD module); normalize to global
    flops_dev = analysis["flops"]
    bytes_dev = analysis["hbm_bytes"]
    coll_dev = analysis["collectives"]["total"]
    mf = cm.model_flops(cfg.active_param_count(), tokens)
    if shape.kind == "train":
        mf *= 1.0  # 6ND already includes fwd+bwd
    else:
        mf /= 3.0  # forward only: 2ND

    global_flops = flops_dev * chips
    terms = cm.roofline_terms(global_flops, bytes_dev * chips, coll_dev * chips, chips)
    hbm_need = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0) - mem.get("alias_size_in_bytes", 0))

    rec.update({
        "status": "ok",
        "chips": chips,
        "tokens": tokens,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collectives": analysis["collectives"],
        "memory": mem,
        "hbm_needed_per_device": hbm_need,
        "fits_hbm": bool(hbm_need < cm.V5E.hbm_bytes),
        "model_flops": mf,
        "useful_flops_ratio": (mf / global_flops) if global_flops else None,
        "roofline": terms,
        "dominant": cm.dominant_term(terms),
    })
    _emit(rec, out_path, quiet)
    return rec


def _emit(rec, out_path, quiet):
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
    if not quiet:
        slim = {k: v for k, v in rec.items() if k not in ("collectives", "memory")}
        print(json.dumps(slim, indent=1, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--plan", default="dp_tp")
    ap.add_argument("--out", default=None)
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args()
    try:
        rec = run(args.arch, args.shape, args.mesh, args.plan, args.out,
                  accum_steps=args.accum)
        sys.exit(0 if rec.get("status") in ("ok", "skipped") else 1)
    except Exception:
        traceback.print_exc()
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"arch": args.arch, "shape": args.shape,
                           "mesh": args.mesh, "plan": args.plan,
                           "status": "error",
                           "error": traceback.format_exc()[-2000:]}, f, indent=1)
        sys.exit(1)


if __name__ == "__main__":
    main()
