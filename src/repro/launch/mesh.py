"""Production mesh builders (pure functions — importing never touches jax
device state)."""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # axis_types only exists on newer jax; older versions default to Auto
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips ('data','model'); multi-pod adds a 2-way
    'pod' axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(shape=(1,), axes=("data",)):
    """Whatever-fits mesh for CPU smoke runs."""
    n_dev = len(jax.devices())
    total = 1
    for s in shape:
        total *= s
    if total > n_dev:
        shape, axes = (n_dev,), ("data",)
    return _make_mesh(shape, axes)
