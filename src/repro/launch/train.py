"""Training launcher — the end-to-end driver (deliverable (b)).

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --preset reduced \
      --steps 50 --batch 8 --seq 128 --plan dp --optimizer adam --lr 3e-4

On this CPU container use --preset reduced (the full presets are exercised
via the dry-run); on a real TPU slice drop --preset to train the full config.
Supports checkpoint save/restore and the paper-mode explicit-collective
runtime (--paper-mode --algorithm ring --compress topk).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="full", choices=("full", "reduced"))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--plan", default="dp")
    ap.add_argument("--optimizer", default="adam")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-clip", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--resume", default=None)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (set before jax init)")
    ap.add_argument("--paper-mode", action="store_true",
                    help="explicit shard_map DP with chosen collective")
    ap.add_argument("--algorithm", default="ring")
    ap.add_argument("--compress", default="none")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    from repro.configs.base import get_config, reduced
    from repro.core import parallelism as par
    from repro.core.compression import make_compressor
    from repro.data.pipeline import SyntheticLM, shard_batch
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as T
    from repro.optim import make_optimizer
    from repro.train import checkpoint as ckpt
    from repro.train import trainer

    cfg = get_config(args.arch)
    if args.preset == "reduced":
        cfg = reduced(cfg)

    n_dev = len(jax.devices())
    mesh = make_host_mesh((n_dev,), ("data",))
    plan = par.make_plan(args.plan if args.plan != "dp_tp" or n_dev > 1 else "dp", mesh)
    optimizer = make_optimizer(args.optimizer, lr=args.lr, grad_clip=args.grad_clip)

    key = jax.random.PRNGKey(args.seed)
    state = trainer.init_state(cfg, optimizer, key)
    start_step = 0
    if args.resume:
        state, start_step = ckpt.restore(args.resume, state)
        print(f"resumed from {args.resume} at step {start_step}")

    data = SyntheticLM(cfg.vocab_size, args.seq, seed=args.seed)

    if args.paper_mode:
        compressor = None if args.compress == "none" else make_compressor(args.compress)
        step_fn = trainer.make_paper_train_step(
            cfg, optimizer, mesh, algorithm=args.algorithm, compression=compressor)
        residual = trainer.zero_residual(state["params"]) if compressor else \
            jax.tree.map(lambda p: jnp.zeros((1,), jnp.float32), {"_": 0})
        jitted = jax.jit(step_fn)

        t0 = time.time()
        for i, batch in enumerate(data.batches(args.batch, args.steps)):
            state, metrics, residual = jitted(state, batch, residual)
            if (i + 1) % args.log_every == 0 or i == 0:
                print(f"step {start_step+i+1}: loss={float(metrics['loss']):.4f} "
                      f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    else:
        jitted = jax.jit(trainer.make_train_step(cfg, optimizer, plan))
        t0 = time.time()
        for i, batch in enumerate(data.batches(args.batch, args.steps)):
            batch = shard_batch(batch, plan)
            state, metrics = jitted(state, batch)
            if (i + 1) % args.log_every == 0 or i == 0:
                print(f"step {start_step+i+1}: loss={float(metrics['loss']):.4f} "
                      f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)

    if args.checkpoint:
        ckpt.save(args.checkpoint, state, start_step + args.steps)
        print(f"saved {args.checkpoint}")


if __name__ == "__main__":
    main()
