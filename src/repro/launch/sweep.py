"""Drive the full dry-run matrix: every (arch × shape × mesh), one process
per combination (jax locks the 512 fake devices at init). Results land in
results/dryrun/<arch>__<shape>__<mesh>__<plan>.json; existing files are
skipped, so the sweep is resumable.

  PYTHONPATH=src python -m repro.launch.sweep --mesh single multi --plan dp_tp
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs.base import ARCH_MODULES, SHAPES, get_config

ARCHS = [
    "gemma3-12b", "phi4-mini-3.8b", "qwen2-vl-2b", "mixtral-8x7b",
    "stablelm-3b", "rwkv6-7b", "yi-9b", "qwen3-moe-30b-a3b",
    "zamba2-2.7b", "musicgen-medium",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", nargs="+", default=["single", "multi"])
    ap.add_argument("--plan", nargs="+", default=["dp_tp"])
    ap.add_argument("--arch", nargs="+", default=ARCHS)
    ap.add_argument("--shape", nargs="+", default=list(SHAPES))
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    combos = [(a, s, m, p) for a in args.arch for s in args.shape
              for m in args.mesh for p in args.plan]
    t_start = time.time()
    n_ok = n_skip = n_err = 0
    for i, (arch, shape, mesh, plan) in enumerate(combos):
        out = os.path.join(args.outdir, f"{arch}__{shape}__{mesh}__{plan}.json")
        if os.path.exists(out):
            with open(out) as f:
                st = json.load(f).get("status")
            if st in ("ok", "skipped"):
                continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh,
               "--plan", plan, "--out", out]
        t0 = time.time()
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env={**os.environ, "PYTHONPATH": "src"})
            status = "?"
            if os.path.exists(out):
                with open(out) as f:
                    status = json.load(f).get("status")
            if status == "ok":
                n_ok += 1
            elif status == "skipped":
                n_skip += 1
            else:
                n_err += 1
                tail = (r.stderr or r.stdout or "")[-800:]
                print(f"[{i+1}/{len(combos)}] {arch} {shape} {mesh} ERROR\n{tail}",
                      flush=True)
                continue
            print(f"[{i+1}/{len(combos)}] {arch} {shape} {mesh} {plan}: "
                  f"{status} ({time.time()-t0:.0f}s)", flush=True)
        except subprocess.TimeoutExpired:
            n_err += 1
            with open(out, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "plan": plan, "status": "error",
                           "error": "timeout"}, f)
            print(f"[{i+1}/{len(combos)}] {arch} {shape} {mesh}: TIMEOUT", flush=True)
    print(f"done in {time.time()-t_start:.0f}s: ok={n_ok} skip={n_skip} err={n_err}")


if __name__ == "__main__":
    main()
