import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf profiler: lower+compile one combo and print trip-weighted top ops by
HBM traffic / FLOPs / collective bytes — the evidence for each hypothesis.

  PYTHONPATH=src python -m repro.launch.profile_combo --arch rwkv6-7b \
      --shape train_4k --plan dp_tp --metric hbm_bytes
"""
import argparse

from repro.configs.base import SHAPES, get_config
from repro.core import hlo_analysis as ha
from repro.launch.dryrun import lower_combo
from repro.launch.mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--plan", default="dp_tp")
    ap.add_argument("--metric", default="hbm_bytes",
                    choices=("hbm_bytes", "flops"))
    ap.add_argument("--n", type=int, default=20)
    ap.add_argument("--collectives", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = make_production_mesh()
    lowered, _ = lower_combo(cfg, SHAPES[args.shape], mesh, args.plan)
    text = lowered.compile().as_text()
    rows = ha.top_ops(text, n=args.n, metric=args.metric)
    total = ha.analyze_hlo_text(text)
    print(f"total flops={total['flops']:.3e} hbm={total['hbm_bytes']:.3e} "
          f"coll={total['total_collective_bytes']:.3e}")
    print(f"--- top {args.n} by {args.metric} (trip-weighted, per device) ---")
    for cost, op, name, shape, hint in rows:
        print(f"{cost:12.4e}  {op:18s} {shape:28s} {hint}")
    if args.collectives:
        print("--- collectives ---")
        for k, v in total["collective_bytes"].items():
            print(f"{k:20s} {v:12.4e} bytes  x{total['collective_counts'][k]:.0f}")


if __name__ == "__main__":
    main()
