"""ShapeDtypeStruct stand-ins for every model input — shardable, weak-type
correct, zero allocation (deliverable (e) step 2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SHAPES, InputShape
from repro.models import layers as L
from repro.models import transformer as T


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape):
    """Abstract inputs for (cfg, shape). Returns a dict:
      train/prefill: batch for loss_fn/forward
      decode:        {"inputs", "cache", "index"} for decode_step
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = {}
        if cfg.frontend != "none":
            batch["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
            if cfg.rope_mode == "mrope":
                batch["positions"] = _sds((3, B, S), jnp.int32)
        else:
            batch["tokens"] = _sds((B, S), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = _sds((B, S), jnp.int32)
        return {"batch": batch}

    # decode: ONE new token against a cache of length seq_len
    cache = jax.eval_shape(lambda: T.init_decode_state(cfg, B, S))
    inputs = ({"embed": _sds((B, cfg.d_model), jnp.bfloat16)}
              if cfg.frontend != "none" else {"token": _sds((B,), jnp.int32)})
    return {
        "inputs": inputs,
        "cache": cache,
        "index": _sds((), jnp.int32),
    }


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """long_500k requires sub-quadratic attention (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: pure full-attention arch — 500k dense-KV "
                       "decode is memory-infeasible; no windowed variant in "
                       "the model card (DESIGN.md §Decode-shape rules)")
    return True, ""
