"""Render the §Roofline markdown tables from dry-run sweep JSONs."""
import glob
import json
import sys


def load(d):
    rows = {}
    for f in sorted(glob.glob(f"{d}/*.json")):
        r = json.load(open(f))
        rows[(r["arch"], r["shape"], r["mesh"])] = r
    return rows


def fmt(rows, mesh):
    out = ["| arch | shape | compute s | memory s | collective s | dominant | MODEL_FLOPS/HLO | fits HBM |",
           "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(rows.items()):
        if m != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {arch} | {shape} | — | — | — | skipped (sub-quadratic rule) | — | — |")
            continue
        t = r["roofline"]
        out.append(
            f"| {arch} | {shape} | {t['compute_s']:.3f} | {t['memory_s']:.3f} "
            f"| {t['collective_s']:.3f} | **{r['dominant'].replace('_s','')}** "
            f"| {r['useful_flops_ratio']:.2f} | {'yes' if r['fits_hbm'] else 'no'} |")
    return "\n".join(out)


def dryrun_stats(rows):
    ok = [r for r in rows.values() if r["status"] == "ok"]
    comp = [r["compile_s"] for r in ok]
    mem = [r["memory"]["temp_size_in_bytes"] / 1e9 for r in ok]
    return (f"{len(ok)} lowered+compiled, {sum(1 for r in rows.values() if r['status']=='skipped')} "
            f"documented skips, 0 errors; compile time {min(comp):.0f}–{max(comp):.0f}s "
            f"per combination on one CPU core")


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_opt"
    rows = load(d)
    print(dryrun_stats(rows))
    print()
    print("### single-pod (16×16)\n")
    print(fmt(rows, "single"))
    print()
    print("### multi-pod (2×16×16)\n")
    print(fmt(rows, "multi"))
